//! Experiment orchestration: turns an [`ExperimentConfig`] into datasets,
//! learners and engine runs, and collects Table-2-style cell reports.
//! This is the layer the CLI (`rust/src/main.rs`), the examples and the
//! benches all drive, so every experiment in EXPERIMENTS.md is a function
//! call away.
//!
//! Learner dispatch is **registry-driven** ([`registry`]): one table maps
//! every [`Task`] to its dataset family, its erased-learner constructor,
//! its merge support and its sweepable hyperparameter — no per-task
//! `match` arms to copy-paste, so every learner in the crate is reachable
//! from the CLI. Engine runs go through the type-erased layer
//! ([`crate::learner::erased`]), which delegates to the same engine code
//! as the generic path (bit-identical results); all parallel engine
//! selections dispatch through the pooled work-stealing executor
//! ([`crate::cv::executor::TreeCvExecutor`]) via the repetition harness.
//!
//! Multi-run workloads batch through ONE executor pool: [`run_sweep`]
//! (one task, a hyperparameter grid) and [`run_select`] (a heterogeneous
//! learner list ranked on a common dataset — model selection in the sense
//! of Mohr & van Rijn's learning-curve selection, scheduled the TreeCV
//! way). [`run_race_sweep`] is the sweep's racing mode (`repro sweep
//! --race`): the same batch dispatched through the executor's
//! cancellation layer with Krueger-style sequential elimination
//! ([`crate::cv::race`]), reported as a [`RaceReport`] with the full
//! elimination trace and work-saved counters.

pub mod paper;
pub mod registry;
pub mod serve;

pub use serve::{format_serve_table, run_serve, ServeReport};

use crate::config::{Engine, ExperimentConfig, StrategyCfg, SweepGrid, Task};
use crate::cv::folds::{Folds, Ordering};
use crate::cv::mergecv::MergeCv;
use crate::cv::race::{self, RaceSpec};
use crate::cv::stats::{run_repetitions, EngineKind, RepetitionResult, RepetitionSpec};
use crate::cv::sweep::{self, SweepOutcome, SweepSpec};
use crate::cv::Strategy;
use crate::data::Dataset;
use crate::learner::erased::{DynLearner, ErasedLearner};
use crate::learner::MergeableLearner;
use crate::metrics::OpCounts;
use crate::Result;
use anyhow::bail;

/// One (task, engine, k) cell of results.
#[derive(Debug, Clone)]
pub struct CellReport {
    pub task: Task,
    pub engine: Engine,
    /// Effective fold count (LOOCV is reported as n).
    pub k: usize,
    pub n: usize,
    pub repetitions: usize,
    pub mean: f64,
    pub std: f64,
    pub mean_wall_secs: f64,
    pub ops: OpCounts,
}

impl CellReport {
    fn from_rep(task: Task, engine: Engine, n: usize, rep: &RepetitionResult) -> Self {
        Self {
            task,
            engine,
            k: rep.spec.k,
            n,
            repetitions: rep.spec.repetitions,
            mean: rep.mean,
            std: rep.std,
            mean_wall_secs: rep.mean_wall_secs,
            ops: rep.ops.clone(),
        }
    }
}

/// Build the dataset for the config's task (synthetic unless `data_path`
/// is given) — the task's registry row decides family and preprocessing.
pub fn build_dataset(cfg: &ExperimentConfig) -> Result<Dataset> {
    registry::entry(cfg.task).dataset.build(cfg)
}

fn engine_kind(engine: Engine) -> Result<EngineKind> {
    Ok(match engine {
        Engine::Treecv => EngineKind::TreeCv,
        Engine::Standard => EngineKind::Standard,
        Engine::ParallelTreecv => EngineKind::ParallelTreeCv,
        Engine::Approx => EngineKind::Approx,
        Engine::Merge => bail!("merge engine is dispatched separately"),
    })
}

/// Run the per-k repetition cells for one (type-erased) learner.
///
/// Dispatch cost note: the engines call `update`/`update_logged`/
/// `evaluate` once per tree NODE (a whole chunk each), never per point —
/// the per-point loops live inside the concrete learner and stay
/// monomorphized — so erasure adds O(k log k) vtable hops + boxed undo
/// tokens per run against O(n log k) point work. `benches/dyn_overhead.rs`
/// measures the ratio (and asserts bit-equality).
fn run_cells(
    learner: &dyn ErasedLearner,
    data: &Dataset,
    cfg: &ExperimentConfig,
) -> Result<Vec<CellReport>> {
    let dyn_learner = DynLearner(learner);
    let mut out = Vec::new();
    for &k_raw in &cfg.ks {
        let k = if k_raw == 0 { data.n } else { k_raw };
        if k > data.n {
            bail!("k = {k} exceeds n = {}", data.n);
        }
        let spec = RepetitionSpec {
            engine: engine_kind(cfg.engine)?,
            ordering: Ordering::from(cfg.ordering),
            strategy: Strategy::from(cfg.strategy),
            k,
            repetitions: cfg.repetitions,
            seed: cfg.seed,
            threads: cfg.threads,
            approx_check: cfg.approx_check,
        };
        let rep = run_repetitions(&dyn_learner, data, &spec)?;
        out.push(CellReport::from_rep(cfg.task, cfg.engine, data.n, &rep));
    }
    Ok(out)
}

/// Izbicki fold-merging cells — generic, because merging needs the
/// concrete [`MergeableLearner`]; the registry's `merge` hooks call this
/// with their concrete learner.
fn run_merge_cells<L: MergeableLearner>(
    learner: &L,
    data: &Dataset,
    cfg: &ExperimentConfig,
) -> Result<Vec<CellReport>> {
    if cfg.strategy == crate::config::StrategyCfg::SaveRevert {
        bail!(
            "engine `merge` cannot honor the save/revert strategy (Izbicki-style fold merging \
             never updates a model in place); refusing to silently run Copy instead"
        );
    }
    let mut out = Vec::new();
    for &k_raw in &cfg.ks {
        let k = if k_raw == 0 { data.n } else { k_raw };
        if k > data.n {
            bail!("k = {k} exceeds n = {}", data.n);
        }
        let mut stats = crate::metrics::RunningStats::default();
        let mut wall = std::time::Duration::ZERO;
        let mut ops = OpCounts::default();
        for r in 0..cfg.repetitions {
            let rep_seed = cfg.seed.wrapping_add(r as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let folds = Folds::new(data.n, k, rep_seed);
            let res = MergeCv.run(learner, data, &folds);
            stats.push(res.estimate);
            wall += res.wall;
            ops = res.ops;
        }
        out.push(CellReport {
            task: cfg.task,
            engine: Engine::Merge,
            k,
            n: data.n,
            repetitions: cfg.repetitions,
            mean: stats.mean(),
            std: stats.std(),
            mean_wall_secs: wall.as_secs_f64() / cfg.repetitions.max(1) as f64,
            ops,
        });
    }
    Ok(out)
}

/// Run the experiment described by `cfg` and return one report per k.
/// Dispatch is fully registry-driven: any task in
/// [`registry::REGISTRY`] works here, through any engine it supports.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Vec<CellReport>> {
    let entry = registry::entry(cfg.task);
    let data = build_dataset(cfg)?;

    if cfg.engine == Engine::Merge {
        let Some(merge) = entry.merge else {
            bail!(
                "task {:?} is not mergeable (Izbicki's assumption does not hold)",
                cfg.task
            );
        };
        return merge(cfg, &data);
    }

    let learner = (entry.build)(cfg, &data)?;
    run_cells(&*learner, &data, cfg)
}

/// Resolve the batch subcommands' single fold count (`ks[0]`; `0` =
/// LOOCV → n), range-checked against the dataset — shared by `run_sweep`
/// and `run_select` so the two cannot drift. Callers have already
/// checked `ks.len() == 1`.
fn resolve_single_k(cfg: &ExperimentConfig, data: &Dataset) -> Result<usize> {
    let k = if cfg.ks[0] == 0 { data.n } else { cfg.ks[0] };
    if k > data.n {
        bail!("k = {k} exceeds n = {}", data.n);
    }
    Ok(k)
}

/// The batch subcommands' shared [`SweepSpec`] derivation — `run_sweep`
/// and `run_select` must schedule their batches identically (same
/// ordering/strategy/repetitions/seed/threads mapping), so it lives in
/// one place.
fn batch_spec(cfg: &ExperimentConfig, k: usize) -> SweepSpec {
    SweepSpec {
        ordering: Ordering::from(cfg.ordering),
        strategies: vec![Strategy::from(cfg.strategy)],
        k,
        repetitions: cfg.repetitions,
        seed: cfg.seed,
        threads: cfg.threads,
    }
}

/// One ranked row of a sweep: a (hyperparameter value, strategy) cell.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Swept parameter name (`lambda` / `alpha`).
    pub param: String,
    pub value: f64,
    pub strategy: StrategyCfg,
    /// Mean CV estimate over the repetitions (the ranking key).
    pub mean: f64,
    /// Sample std over the repetitions.
    pub std: f64,
    /// Counters from the cell's last repetition.
    pub ops: OpCounts,
}

/// Result of `repro sweep`: one row per grid point, ranked by mean loss
/// (best first), plus the pooled-execution accounting.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub task: Task,
    pub n: usize,
    pub k: usize,
    pub repetitions: usize,
    /// Worker-pool size the sweep actually used.
    pub threads: usize,
    /// Executor pools spawned by the whole sweep (per-pool counter) — 1
    /// for a multi-worker pool, 0 for `--threads 1` (inline), never one
    /// per run.
    pub pool_spawns: u64,
    /// Wall-clock of the whole pooled batch (runs overlap, so there is no
    /// meaningful per-row wall).
    pub total_wall_secs: f64,
    /// Rows ranked by mean loss ascending.
    pub points: Vec<SweepPoint>,
}

/// The sweep subcommand's shared front half — validate the grid, build
/// the dataset, resolve the fold count and construct one learner per
/// grid value — so the exhaustive ([`run_sweep`]) and racing
/// ([`run_race_sweep`]) modes evaluate EXACTLY the same batch and differ
/// only in scheduling.
fn sweep_inputs(
    cfg: &ExperimentConfig,
) -> Result<(SweepGrid, Dataset, usize, Vec<Box<dyn ErasedLearner>>)> {
    let Some(grid) = &cfg.sweep else {
        bail!("sweep needs a grid — pass --sweep name=v1,v2,... (e.g. lambda=0.1,0.01,0.001)");
    };
    if cfg.ks.len() != 1 {
        bail!("sweep uses a single fold count; got ks = {:?}", cfg.ks);
    }
    let entry = registry::entry(cfg.task);
    // Name + domain validation is the same shared rule the select list
    // uses; materialize the per-value configs up front so a bad grid
    // fails before the potentially expensive dataset build, with no
    // second validation pass.
    let value_cfgs: Vec<ExperimentConfig> = grid
        .values
        .iter()
        .map(|&v| {
            let mut value_cfg = cfg.clone();
            registry::checked_apply_param(&mut value_cfg, cfg.task, &grid.param, v)?;
            Ok(value_cfg)
        })
        .collect::<Result<_>>()?;

    let data = build_dataset(cfg)?;
    let k = resolve_single_k(cfg, &data)?;
    let learners: Vec<Box<dyn ErasedLearner>> =
        value_cfgs.iter().map(|c| (entry.build)(c, &data)).collect::<Result<_>>()?;
    Ok((grid.clone(), data, k, learners))
}

/// Run the tuning workload described by `cfg`: every (grid value ×
/// repetition) TreeCV run through ONE pooled executor
/// ([`crate::cv::sweep::run_sweep_erased`]), returning rows ranked by
/// mean loss. Learners are built per grid value through the task's
/// registry constructor; fold assignments are shared across grid values,
/// so the hyperparameter is the only difference between rows.
pub fn run_sweep(cfg: &ExperimentConfig) -> Result<SweepReport> {
    let (grid, data, k, learners) = sweep_inputs(cfg)?;
    let refs: Vec<&dyn ErasedLearner> = learners.iter().map(|b| &**b).collect();
    let spec = batch_spec(cfg, k);
    let outcome: SweepOutcome = sweep::run_sweep_erased(&refs, &data, &spec)?;

    let mut points: Vec<SweepPoint> = outcome
        .cells
        .iter()
        .map(|c| SweepPoint {
            param: grid.param.clone(),
            value: grid.values[c.config],
            strategy: StrategyCfg::from(c.strategy),
            mean: c.mean,
            std: c.std,
            ops: c.ops.clone(),
        })
        .collect();
    points.sort_by(|a, b| a.mean.total_cmp(&b.mean).then(a.value.total_cmp(&b.value)));
    Ok(SweepReport {
        task: cfg.task,
        n: data.n,
        k,
        repetitions: cfg.repetitions,
        threads: outcome.threads,
        pool_spawns: outcome.pool_spawns,
        total_wall_secs: outcome.total_wall.as_secs_f64(),
        points,
    })
}

/// One ranked row of a racing sweep: a (hyperparameter value, strategy)
/// cell plus its racing status.
#[derive(Debug, Clone)]
pub struct RacePoint {
    /// Swept parameter name (`lambda` / `alpha`).
    pub param: String,
    pub value: f64,
    pub strategy: StrategyCfg,
    /// Mean CV estimate over the counted repetitions.
    pub mean: f64,
    /// Sample std over the counted repetitions.
    pub std: f64,
    /// Repetitions the aggregate counts: the elimination boundary for an
    /// eliminated value, the full repetition count for a survivor.
    pub reps_used: usize,
    /// The round (0-based) that eliminated this value, if any.
    pub eliminated_round: Option<usize>,
    /// Counters from the cell's last counted repetition.
    pub ops: OpCounts,
}

/// One row of the race's elimination trace, in (round, cell) order —
/// deterministic given the seed.
#[derive(Debug, Clone)]
pub struct RaceTracePoint {
    pub round: usize,
    /// Repetitions counted at this round's boundary.
    pub reps_used: usize,
    pub param: String,
    pub value: f64,
    pub strategy: StrategyCfg,
    /// Mean estimate over the counted repetitions.
    pub mean: f64,
    /// Incumbent wins in the paired sign test (0 on the incumbent row).
    pub wins: usize,
    /// Non-tied repetitions in the test (0 on the incumbent row).
    pub n_eff: usize,
    pub p_value: f64,
    pub eliminated: bool,
}

/// Result of `repro sweep --race`: survivors ranked first, eliminated
/// values after (latest-surviving first), plus the full
/// [`EliminationTrace`](crate::cv::race::EliminationTrace) and the
/// work-saved accounting.
#[derive(Debug, Clone)]
pub struct RaceReport {
    pub task: Task,
    pub n: usize,
    pub k: usize,
    pub repetitions: usize,
    /// Decision rounds the race was configured with.
    pub rounds: usize,
    /// Sign-test significance level.
    pub alpha: f64,
    /// Worker-pool size the race actually used.
    pub threads: usize,
    /// Executor pools spawned by the whole race — 1 for a multi-worker
    /// pool, 0 for `--threads 1` (inline), never one per run.
    pub pool_spawns: u64,
    /// Wall-clock of the whole raced batch.
    pub total_wall_secs: f64,
    /// Work-saved counters: runs the grid scheduled…
    pub runs_scheduled: usize,
    /// …runs that ran to completion…
    pub runs_completed: usize,
    /// …and runs cancelled mid-batch by eliminations (scheduling-
    /// dependent, unlike the trace: a fast pool may finish a loser's
    /// runs before its cancellation lands).
    pub runs_cancelled: usize,
    /// Tree tasks dropped by those cancellations.
    pub tree_tasks_cancelled: u64,
    /// Ranked rows: survivors by mean ascending, then eliminated values
    /// by elimination round descending (lasted longer ranks higher).
    pub points: Vec<RacePoint>,
    /// The per-round decision record.
    pub trace: Vec<RaceTracePoint>,
}

/// Run the tuning workload as a race (`repro sweep --race`): the same
/// batch as [`run_sweep`] — same grid, dataset, folds, seeds — but
/// scheduled by [`crate::cv::race::run_race_erased`], which eliminates
/// losing values at round boundaries and cancels their outstanding runs.
/// `alpha = 0` reproduces the exhaustive table bit for bit.
pub fn run_race_sweep(cfg: &ExperimentConfig) -> Result<RaceReport> {
    let (grid, data, k, learners) = sweep_inputs(cfg)?;
    let refs: Vec<&dyn ErasedLearner> = learners.iter().map(|b| &**b).collect();
    let spec =
        RaceSpec { sweep: batch_spec(cfg, k), rounds: cfg.race_rounds, alpha: cfg.race_alpha };
    let outcome = race::run_race_erased(&refs, &data, &spec)?;

    let mut points: Vec<RacePoint> = outcome
        .cells
        .iter()
        .map(|c| RacePoint {
            param: grid.param.clone(),
            value: grid.values[c.config],
            strategy: StrategyCfg::from(c.strategy),
            mean: c.mean,
            std: c.std,
            reps_used: c.reps_used,
            eliminated_round: c.eliminated_round,
            ops: c.ops.clone(),
        })
        .collect();
    // Survivors first (ranked exactly as the exhaustive sweep ranks, so
    // alpha = 0 reproduces its table order), then eliminated values by
    // how long they lasted; means across different rep counts are not
    // comparable, so elimination round outranks mean there.
    points.sort_by(|a, b| match (a.eliminated_round, b.eliminated_round) {
        (None, None) => a.mean.total_cmp(&b.mean).then(a.value.total_cmp(&b.value)),
        (None, Some(_)) => std::cmp::Ordering::Less,
        (Some(_), None) => std::cmp::Ordering::Greater,
        (Some(ra), Some(rb)) => rb
            .cmp(&ra)
            .then(a.mean.total_cmp(&b.mean))
            .then(a.value.total_cmp(&b.value)),
    });
    let trace = outcome
        .trace
        .rows
        .iter()
        .map(|r| RaceTracePoint {
            round: r.round,
            reps_used: r.reps_used,
            param: grid.param.clone(),
            value: grid.values[r.config],
            strategy: StrategyCfg::from(r.strategy),
            mean: r.mean,
            wins: r.wins,
            n_eff: r.n_eff,
            p_value: r.p_value,
            eliminated: r.eliminated,
        })
        .collect();
    Ok(RaceReport {
        task: cfg.task,
        n: data.n,
        k,
        repetitions: cfg.repetitions,
        rounds: cfg.race_rounds,
        alpha: cfg.race_alpha,
        threads: outcome.threads,
        pool_spawns: outcome.pool_spawns,
        total_wall_secs: outcome.total_wall.as_secs_f64(),
        runs_scheduled: outcome.runs_scheduled,
        runs_completed: outcome.runs_completed,
        runs_cancelled: outcome.runs_cancelled,
        tree_tasks_cancelled: outcome.tasks_cancelled,
        points,
        trace,
    })
}

/// One ranked row of a model-selection run: a learner family (with its
/// optional hyperparameter override).
#[derive(Debug, Clone)]
pub struct SelectPoint {
    /// Display label, e.g. `pegasos(lambda=1e-4)` or `knn`.
    pub learner: String,
    pub task: Task,
    pub strategy: StrategyCfg,
    /// Mean CV estimate over the repetitions (the ranking key).
    pub mean: f64,
    /// Sample std over the repetitions.
    pub std: f64,
    /// Counters from the cell's last repetition.
    pub ops: OpCounts,
}

/// Result of `repro select`: heterogeneous learner families ranked by
/// mean CV loss on a common dataset, all scheduled through ONE pooled
/// executor.
#[derive(Debug, Clone)]
pub struct SelectReport {
    pub n: usize,
    pub k: usize,
    pub repetitions: usize,
    /// Worker-pool size the run actually used.
    pub threads: usize,
    /// Executor pools spawned by the whole selection (per-pool counter) —
    /// 1 for a multi-worker pool, 0 for `--threads 1`, never one per run
    /// or per family.
    pub pool_spawns: u64,
    /// Wall-clock of the whole pooled batch.
    pub total_wall_secs: f64,
    /// Rows ranked by mean loss ascending.
    pub points: Vec<SelectPoint>,
}

/// Run the model-selection workload described by `cfg`: every (learner ×
/// repetition) TreeCV run through ONE pooled executor, via the
/// heterogeneous sweep ([`crate::cv::sweep::run_sweep_erased`]).
///
/// All chosen learners must share one dataset family (the registry's
/// [`registry::DatasetKind`]) so their CV losses are computed on a common
/// dataset — ranking a density estimator's NLL against a classifier's
/// error rate is meaningless, and is rejected. Fold assignments are
/// shared across learners, so the learner really is the only difference
/// between rows.
pub fn run_select(cfg: &ExperimentConfig) -> Result<SelectReport> {
    let Some(list) = &cfg.learners else {
        bail!(
            "select needs a learner list — pass --learners task[:param=value],... \
             (e.g. pegasos:lambda=1e-4,naive_bayes,knn,perceptron)"
        );
    };
    if cfg.ks.len() != 1 {
        bail!("select uses a single fold count; got ks = {:?}", cfg.ks);
    }
    // SelectList::parse guarantees non-emptiness, but the fields are pub —
    // guard against a programmatically built empty list.
    if list.entries.is_empty() {
        bail!("select needs at least one learner in the list");
    }
    let kind = registry::entry(list.entries[0].task).dataset;
    for e in &list.entries {
        let other = registry::entry(e.task).dataset;
        if other != kind {
            bail!(
                "select mixes dataset families: {} runs on {kind:?} but {} runs on {other:?} — \
                 model selection needs one common dataset (and comparable losses)",
                list.entries[0].task.name(),
                e.task.name(),
            );
        }
    }

    let data = kind.build(cfg)?;
    let k = resolve_single_k(cfg, &data)?;
    let mut learners: Vec<Box<dyn ErasedLearner>> = Vec::with_capacity(list.entries.len());
    for e in &list.entries {
        let entry = registry::entry(e.task);
        if !entry.comparable_loss {
            bail!(
                "task {} is a structural test oracle — its \"loss\" is a correctness \
                 fingerprint, not a statistical metric, so it cannot be ranked in a model \
                 selection (it still runs under `repro cv`)",
                e.task.name()
            );
        }
        let mut learner_cfg = cfg.clone();
        learner_cfg.task = e.task;
        if let Some(p) = &e.param {
            registry::checked_apply_param(&mut learner_cfg, e.task, &p.name, p.value)?;
        }
        learners.push((entry.build)(&learner_cfg, &data)?);
    }
    let refs: Vec<&dyn ErasedLearner> = learners.iter().map(|b| &**b).collect();
    let outcome = sweep::run_sweep_erased(&refs, &data, &batch_spec(cfg, k))?;

    let mut points: Vec<SelectPoint> = outcome
        .cells
        .iter()
        .map(|c| SelectPoint {
            learner: list.entries[c.config].label(),
            task: list.entries[c.config].task,
            strategy: StrategyCfg::from(c.strategy),
            mean: c.mean,
            std: c.std,
            ops: c.ops.clone(),
        })
        .collect();
    points.sort_by(|a, b| a.mean.total_cmp(&b.mean).then_with(|| a.learner.cmp(&b.learner)));
    Ok(SelectReport {
        n: data.n,
        k,
        repetitions: cfg.repetitions,
        threads: outcome.threads,
        pool_spawns: outcome.pool_spawns,
        total_wall_secs: outcome.total_wall.as_secs_f64(),
        points,
    })
}

/// Pretty-print a sweep as its ranked table (the `sweep` CLI's default
/// output; the schema is documented in EXPERIMENTS.md).
pub fn format_sweep_table(report: &SweepReport) -> String {
    let mut s = format!(
        "sweep task={} n={} k={} reps={} threads={} pool_spawns={} total_wall={:.4}s\n",
        report.task.name(),
        report.n,
        report.k,
        report.repetitions,
        report.threads,
        report.pool_spawns,
        report.total_wall_secs,
    );
    s.push_str(&format!(
        "{:>4} {:>10} {:>14} {:>12} {:>12} {:>12} {:>14}\n",
        "rank", "param", "value", "strategy", "mean", "std", "pts_updated"
    ));
    for (i, p) in report.points.iter().enumerate() {
        s.push_str(&format!(
            "{:>4} {:>10} {:>14e} {:>12} {:>12.6} {:>12.6} {:>14}\n",
            i + 1,
            p.param,
            p.value,
            p.strategy.name(),
            p.mean,
            p.std,
            p.ops.points_updated,
        ));
    }
    s
}

/// Pretty-print a race as its ranked table plus the elimination trace
/// (the `sweep --race` CLI's default output; the schema is documented in
/// EXPERIMENTS.md).
pub fn format_race_table(report: &RaceReport) -> String {
    let mut s = format!(
        "race task={} n={} k={} reps={} rounds={} alpha={} threads={} pool_spawns={} \
         total_wall={:.4}s\n",
        report.task.name(),
        report.n,
        report.k,
        report.repetitions,
        report.rounds,
        report.alpha,
        report.threads,
        report.pool_spawns,
        report.total_wall_secs,
    );
    s.push_str(&format!(
        "work_saved: runs_scheduled={} runs_completed={} runs_cancelled={} \
         tree_tasks_cancelled={}\n",
        report.runs_scheduled,
        report.runs_completed,
        report.runs_cancelled,
        report.tree_tasks_cancelled,
    ));
    s.push_str(&format!(
        "{:>4} {:>10} {:>14} {:>12} {:>12} {:>12} {:>5} {:>10}\n",
        "rank", "param", "value", "strategy", "mean", "std", "reps", "status"
    ));
    for (i, p) in report.points.iter().enumerate() {
        let status = match p.eliminated_round {
            Some(r) => format!("out@r{r}"),
            None => "survived".to_string(),
        };
        s.push_str(&format!(
            "{:>4} {:>10} {:>14e} {:>12} {:>12.6} {:>12.6} {:>5} {:>10}\n",
            i + 1,
            p.param,
            p.value,
            p.strategy.name(),
            p.mean,
            p.std,
            p.reps_used,
            status,
        ));
    }
    s.push_str("trace:\n");
    s.push_str(&format!(
        "{:>5} {:>5} {:>14} {:>12} {:>5} {:>6} {:>10} {:>10}\n",
        "round", "reps", "value", "mean", "wins", "n_eff", "p", "decision"
    ));
    for t in &report.trace {
        s.push_str(&format!(
            "{:>5} {:>5} {:>14e} {:>12.6} {:>5} {:>6} {:>10.6} {:>10}\n",
            t.round,
            t.reps_used,
            t.value,
            t.mean,
            t.wins,
            t.n_eff,
            t.p_value,
            if t.eliminated { "eliminate" } else { "keep" },
        ));
    }
    s
}

/// Pretty-print a model-selection run as its ranked table (the `select`
/// CLI's default output; the schema is documented in EXPERIMENTS.md).
pub fn format_select_table(report: &SelectReport) -> String {
    let mut s = format!(
        "select n={} k={} reps={} threads={} pool_spawns={} total_wall={:.4}s\n",
        report.n,
        report.k,
        report.repetitions,
        report.threads,
        report.pool_spawns,
        report.total_wall_secs,
    );
    s.push_str(&format!(
        "{:>4} {:<28} {:>12} {:>12} {:>12} {:>14}\n",
        "rank", "learner", "strategy", "mean", "std", "pts_updated"
    ));
    for (i, p) in report.points.iter().enumerate() {
        s.push_str(&format!(
            "{:>4} {:<28} {:>12} {:>12.6} {:>12.6} {:>14}\n",
            i + 1,
            p.learner,
            p.strategy.name(),
            p.mean,
            p.std,
            p.ops.points_updated,
        ));
    }
    s
}

/// Pretty-print reports as an aligned text table (the CLI's default output).
pub fn format_table(reports: &[CellReport]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<12} {:<16} {:>8} {:>9} {:>5} {:>12} {:>12} {:>12} {:>14}\n",
        "task", "engine", "k", "n", "reps", "mean", "std", "wall(s)", "pts_updated"
    ));
    for r in reports {
        s.push_str(&format!(
            "{:<12} {:<16} {:>8} {:>9} {:>5} {:>12.6} {:>12.6} {:>12.4} {:>14}\n",
            r.task.name(),
            r.engine.name(),
            r.k,
            r.n,
            r.repetitions,
            r.mean,
            r.std,
            r.mean_wall_secs,
            r.ops.points_updated,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OrderingCfg, SelectList, StrategyCfg};

    fn tiny_cfg(task: Task, engine: Engine) -> ExperimentConfig {
        ExperimentConfig {
            task,
            engine,
            ordering: OrderingCfg::Fixed,
            strategy: StrategyCfg::Copy,
            n: 200,
            ks: vec![5],
            repetitions: 3,
            seed: 1,
            lambda: Some(1e-4),
            alpha: 0.0,
            data_path: None,
            out: None,
            sweep: None,
            learners: None,
            threads: 0,
            race: false,
            race_rounds: 4,
            race_alpha: 0.05,
            approx_check: false,
        }
    }

    #[test]
    fn runs_every_registry_task_with_treecv() {
        // Every runtime-free registry task is CLI-reachable end to end;
        // runtime-gated tasks must either run (artifact-equipped
        // environment) or fail with the clean PJRT-unavailable error.
        for e in registry::REGISTRY {
            let cfg = tiny_cfg(e.task, Engine::Treecv);
            match run_experiment(&cfg) {
                Ok(reports) => {
                    assert_eq!(reports.len(), 1, "{:?}", e.task);
                    assert!(reports[0].mean.is_finite(), "{:?}", e.task);
                }
                Err(err) => {
                    assert!(e.requires_runtime, "{:?} failed: {err}", e.task);
                    let msg = format!("{err}");
                    assert!(
                        msg.contains("xla") || msg.contains("artifact") || msg.contains("manifest"),
                        "{:?}: unexpected error `{msg}`",
                        e.task
                    );
                }
            }
        }
    }

    #[test]
    fn new_tasks_run_on_parallel_engine_too() {
        for task in [Task::Knn, Task::Perceptron, Task::Multiset] {
            let cfg = tiny_cfg(task, Engine::ParallelTreecv);
            let reports = run_experiment(&cfg).unwrap();
            assert!(reports[0].mean.is_finite(), "{task:?}");
        }
    }

    #[test]
    fn approx_engine_runs_convex_tasks_and_rejects_the_rest() {
        for task in [Task::Ridge, Task::Pegasos, Task::Lsqsgd] {
            let mut cfg = tiny_cfg(task, Engine::Approx);
            cfg.lambda = Some(1.0);
            cfg.approx_check = true;
            let reports = run_experiment(&cfg).unwrap();
            assert!(reports[0].mean.is_finite(), "{task:?}");
            assert_eq!(reports[0].ops.corrections, 5, "{task:?}");
            assert!(reports[0].ops.exact_gap_max.is_finite(), "{task:?}");
        }
        // Ridge's correction is exact up to rounding, so its checked gap
        // is pinned tight end to end (λ from the config, default 1.0).
        let mut cfg = tiny_cfg(Task::Ridge, Engine::Approx);
        cfg.lambda = Some(1.0);
        cfg.approx_check = true;
        let reports = run_experiment(&cfg).unwrap();
        assert!(reports[0].ops.exact_gap_max <= 1e-8, "{:e}", reports[0].ops.exact_gap_max);
        // Non-convex tasks are a hard error naming the capability.
        let cfg = tiny_cfg(Task::Knn, Engine::Approx);
        let err = run_experiment(&cfg).unwrap_err();
        assert!(format!("{err}").contains("one-step held-out correction"), "{err}");
    }

    #[test]
    fn loocv_k_zero_expands_to_n() {
        let mut cfg = tiny_cfg(Task::Density, Engine::Treecv);
        cfg.ks = vec![0];
        cfg.repetitions = 1;
        let reports = run_experiment(&cfg).unwrap();
        assert_eq!(reports[0].k, 200);
    }

    #[test]
    fn merge_engine_rejects_nonmergeable() {
        let cfg = tiny_cfg(Task::Pegasos, Engine::Merge);
        assert!(run_experiment(&cfg).is_err());
    }

    #[test]
    fn save_revert_honored_by_treecv_and_parallel_engines() {
        for engine in [Engine::Treecv, Engine::ParallelTreecv] {
            let mut cfg = tiny_cfg(Task::Density, engine);
            cfg.strategy = StrategyCfg::SaveRevert;
            let reports = run_experiment(&cfg).unwrap();
            assert!(reports[0].mean.is_finite(), "{engine:?}");
            // The strategy actually ran: interior nodes account as one
            // fork snapshot OR two restores each (k − 1 = 4 interior).
            let ops = &reports[0].ops;
            assert_eq!(2 * ops.model_copies + ops.model_restores, 8, "{engine:?}");
        }
    }

    #[test]
    fn save_revert_on_standard_or_merge_is_a_hard_error() {
        let mut cfg = tiny_cfg(Task::Density, Engine::Standard);
        cfg.strategy = StrategyCfg::SaveRevert;
        let err = run_experiment(&cfg).unwrap_err();
        assert!(format!("{err}").contains("save/revert"), "{err}");

        let mut cfg = tiny_cfg(Task::Density, Engine::Merge);
        cfg.strategy = StrategyCfg::SaveRevert;
        let err = run_experiment(&cfg).unwrap_err();
        assert!(format!("{err}").contains("save/revert"), "{err}");
    }

    #[test]
    fn merge_engine_works_for_naive_bayes_and_knn() {
        let cfg = tiny_cfg(Task::NaiveBayes, Engine::Merge);
        let reports = run_experiment(&cfg).unwrap();
        assert!(reports[0].mean.is_finite());
        assert_eq!(reports[0].ops.points_updated, 200);

        let cfg = tiny_cfg(Task::Knn, Engine::Merge);
        let reports = run_experiment(&cfg).unwrap();
        assert!(reports[0].mean.is_finite());
    }

    #[test]
    fn oversized_k_is_an_error() {
        let mut cfg = tiny_cfg(Task::Pegasos, Engine::Treecv);
        cfg.ks = vec![9999];
        assert!(run_experiment(&cfg).is_err());
    }

    fn sweep_cfg(task: Task, grid: &str) -> ExperimentConfig {
        ExperimentConfig {
            ks: vec![4],
            repetitions: 2,
            threads: 2,
            sweep: Some(crate::config::SweepGrid::parse(grid).unwrap()),
            ..tiny_cfg(task, Engine::ParallelTreecv)
        }
    }

    #[test]
    fn sweep_ranks_by_mean_loss() {
        let report = run_sweep(&sweep_cfg(Task::Pegasos, "lambda=1e-3,1e-4,1e-5")).unwrap();
        assert_eq!(report.points.len(), 3);
        assert!(report.points.windows(2).all(|w| w[0].mean <= w[1].mean));
        assert!(report.points.iter().all(|p| p.mean.is_finite() && p.param == "lambda"));
        // Exactly one multi-worker pool for the whole sweep, read off the
        // executor's own per-pool counter.
        assert_eq!(report.pool_spawns, 1);
        assert_eq!(report.threads, 2);
        let table = format_sweep_table(&report);
        assert!(table.contains("rank"));
        assert!(table.contains("pool_spawns="));
        assert_eq!(table.lines().count(), 2 + 3);
    }

    #[test]
    fn sweep_rejects_bad_grids() {
        // No grid at all.
        let mut cfg = sweep_cfg(Task::Pegasos, "lambda=1e-4");
        cfg.sweep = None;
        assert!(run_sweep(&cfg).is_err());
        // Unsupported task.
        assert!(run_sweep(&sweep_cfg(Task::Density, "lambda=1e-4")).is_err());
        // Wrong parameter for the task.
        assert!(run_sweep(&sweep_cfg(Task::Pegasos, "alpha=0.1")).is_err());
        // Non-positive values.
        assert!(run_sweep(&sweep_cfg(Task::Pegasos, "lambda=0")).is_err());
        // Multiple ks.
        let mut cfg = sweep_cfg(Task::Pegasos, "lambda=1e-4");
        cfg.ks = vec![4, 8];
        assert!(run_sweep(&cfg).is_err());
    }

    #[test]
    fn sweep_runs_every_sweepable_task() {
        for (task, grid) in [
            (Task::Pegasos, "lambda=1e-4,1e-5"),
            (Task::Ridge, "lambda=0.5,1.0"),
            (Task::Lsqsgd, "alpha=0.05,0.1"),
        ] {
            let report = run_sweep(&sweep_cfg(task, grid)).unwrap();
            assert_eq!(report.points.len(), 2, "{task:?}");
            assert!(report.points[0].mean.is_finite(), "{task:?}");
        }
    }

    #[test]
    fn race_alpha_zero_reproduces_exhaustive_sweep_report() {
        let mut cfg = sweep_cfg(Task::Pegasos, "lambda=1e-3,1e-4,1e-5");
        cfg.repetitions = 4;
        cfg.race_alpha = 0.0;
        cfg.race_rounds = 2;
        let race = run_race_sweep(&cfg).unwrap();
        let sweep = run_sweep(&cfg).unwrap();
        // Nothing eliminated, nothing cancelled, and the ranked rows are
        // the exhaustive rows bit for bit, in the same order.
        assert_eq!(race.runs_cancelled, 0);
        assert_eq!(race.runs_completed, race.runs_scheduled);
        assert_eq!(race.points.len(), sweep.points.len());
        for (rp, sp) in race.points.iter().zip(&sweep.points) {
            assert_eq!(rp.eliminated_round, None);
            assert_eq!(rp.reps_used, cfg.repetitions);
            assert_eq!(rp.value, sp.value);
            assert_eq!(rp.mean.to_bits(), sp.mean.to_bits());
            assert_eq!(rp.std.to_bits(), sp.std.to_bits());
        }
        // One trace row per (round, value).
        assert_eq!(race.trace.len(), 2 * 3);
        let table = format_race_table(&race);
        assert!(table.contains("work_saved:"));
        assert!(table.contains("survived"));
        assert!(table.contains("trace:"));
    }

    #[test]
    fn race_report_ranks_survivors_before_eliminated() {
        let mut cfg = sweep_cfg(Task::Ridge, "lambda=0.1,1000000.0");
        cfg.repetitions = 8;
        cfg.threads = 1;
        cfg.race_rounds = 4;
        cfg.race_alpha = 0.5;
        let report = run_race_sweep(&cfg).unwrap();
        assert_eq!(report.points.len(), 2);
        let mut seen_eliminated = false;
        for p in &report.points {
            if p.eliminated_round.is_some() {
                seen_eliminated = true;
                assert!(p.reps_used < cfg.repetitions);
            } else {
                assert!(!seen_eliminated, "survivor ranked below an eliminated value");
                assert_eq!(p.reps_used, cfg.repetitions);
            }
        }
    }

    fn select_cfg(learners: &str) -> ExperimentConfig {
        ExperimentConfig {
            ks: vec![4],
            repetitions: 2,
            threads: 2,
            learners: Some(SelectList::parse(learners).unwrap()),
            ..tiny_cfg(Task::Pegasos, Engine::ParallelTreecv)
        }
    }

    #[test]
    fn select_ranks_heterogeneous_families_through_one_pool() {
        let cfg = select_cfg("pegasos:lambda=1e-4,naive_bayes,knn,perceptron");
        let report = run_select(&cfg).unwrap();
        assert_eq!(report.points.len(), 4);
        assert!(report.points.windows(2).all(|w| w[0].mean <= w[1].mean), "ranked");
        assert!(report.points.iter().all(|p| p.mean.is_finite()));
        // ≥ 3 learner families, exactly ONE pool spawn (per-pool counter).
        assert_eq!(report.pool_spawns, 1);
        assert_eq!(report.threads, 2);
        let labels: Vec<&str> = report.points.iter().map(|p| p.learner.as_str()).collect();
        assert!(labels.contains(&"pegasos(lambda=1e-4)"), "{labels:?}");
        assert!(labels.contains(&"knn"), "{labels:?}");
        let table = format_select_table(&report);
        assert!(table.contains("rank"));
        assert!(table.contains("pool_spawns=1"));
        assert_eq!(table.lines().count(), 2 + 4);
    }

    #[test]
    fn select_shares_folds_across_families() {
        // Two identical entries must produce bit-identical rows: the
        // learner really is the only degree of freedom.
        let cfg = select_cfg("pegasos:lambda=1e-4,pegasos:lambda=1e-4");
        let report = run_select(&cfg).unwrap();
        assert_eq!(report.points.len(), 2);
        assert_eq!(report.points[0].mean.to_bits(), report.points[1].mean.to_bits());
        assert_eq!(report.points[0].std.to_bits(), report.points[1].std.to_bits());
    }

    #[test]
    fn select_rejects_bad_lists() {
        // No list.
        let mut cfg = select_cfg("pegasos,knn");
        cfg.learners = None;
        assert!(run_select(&cfg).is_err());
        // Mixed dataset families (classification vs regression).
        let err = run_select(&select_cfg("pegasos,ridge")).unwrap_err();
        assert!(format!("{err}").contains("dataset families"), "{err}");
        // Parameter on a task without one.
        assert!(run_select(&select_cfg("knn:lambda=0.5,pegasos")).is_err());
        // Wrong parameter name for the task.
        assert!(run_select(&select_cfg("pegasos:alpha=0.5,knn")).is_err());
        // Non-positive override values error cleanly (never a constructor
        // panic).
        let err = run_select(&select_cfg("pegasos:lambda=0,knn")).unwrap_err();
        assert!(format!("{err}").contains("must be > 0"), "{err}");
        // The multiset structural oracle shares density's dataset family
        // but its hash-fingerprint "loss" is not a rankable metric.
        let err = run_select(&select_cfg("density,multiset")).unwrap_err();
        assert!(format!("{err}").contains("structural test oracle"), "{err}");
        // Multiple ks.
        let mut cfg = select_cfg("pegasos,knn");
        cfg.ks = vec![4, 8];
        assert!(run_select(&cfg).is_err());
    }

    #[test]
    fn table_formatting_contains_rows() {
        let cfg = tiny_cfg(Task::Pegasos, Engine::Treecv);
        let reports = run_experiment(&cfg).unwrap();
        let table = format_table(&reports);
        assert!(table.contains("pegasos"));
        assert!(table.contains("treecv"));
    }
}
