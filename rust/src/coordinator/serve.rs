//! `repro serve` — the streaming CV service: ROADMAP's "heavy traffic"
//! scenario in miniature. One persistent executor pool primes a baseline
//! TreeCV estimate, then a line protocol on stdin appends row batches
//! ([`crate::data::folded::FoldedDataset::append_rows`]) and keeps the
//! estimate warm through the O(log k)-per-fold incremental refresh engine
//! ([`crate::cv::refresh`]) — answering estimate queries at any point and
//! reporting throughput and estimate-staleness metrics at shutdown.
//!
//! ## Line protocol (stdin → stdout)
//!
//! | input                 | effect / reply                                |
//! |-----------------------|-----------------------------------------------|
//! | `row <y> <x1>..<xd>`  | buffer one row; auto-applies every `--batch`  |
//! | `flush`               | apply buffered rows now → `applied …` line    |
//! | `query`               | `estimate <v> pending <p>` (no flush: `p` is  |
//! |                       | the staleness — buffered rows not yet folded; |
//! |                       | with `--engine approx` a stale query folds    |
//! |                       | the pending rows into a one-step-corrected    |
//! |                       | quick estimate instead of ignoring them)      |
//! | `retire <count>`      | drop the `count` oldest rows (sliding window) |
//! |                       | and re-prime → `retired …` line               |
//! | `stats`               | one-line counter snapshot                     |
//! | `quit` / `exit`       | stop reading; EOF acts the same               |
//! | blank / `# …`         | ignored                                       |
//!
//! Malformed input never kills the service: it answers `err …` and keeps
//! reading. Applied batches reply
//! `applied rows=<b> touched=<t> subtrees=<s> estimate=<v>` so a driving
//! process can observe refresh cost live. Retiring invalidates every
//! cached interior model (row ids shift under the fold layout), so the
//! service re-primes from scratch — the one full-cost operation.

use super::{build_dataset, registry, resolve_single_k};
use crate::config::{Engine, ExperimentConfig, Task};
use crate::cv::approx::ApproxCv;
use crate::cv::executor::TreeCvExecutor;
use crate::cv::folds::{Folds, Ordering};
use crate::cv::refresh::RefreshSession;
use crate::cv::Strategy;
use crate::data::folded::FoldedDataset;
use crate::data::Dataset;
use crate::learner::erased::DynLearner;
use crate::metrics::{RunningStats, Timer};
use crate::Result;
use anyhow::bail;
use std::io::{BufRead, Write};
use std::time::Duration;

/// Final metrics of one `repro serve` session (rendered by
/// [`format_serve_table`] or as JSON via `ToJson`).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub task: Task,
    /// Fold count (fixed for the session; chunks grow with appends).
    pub k: usize,
    /// Window size when the stream ended.
    pub n_final: usize,
    /// Worker-pool size used by prime runs (refreshes are sequential).
    pub threads: usize,
    /// Rows accepted over the whole session.
    pub rows_ingested: u64,
    /// Rows dropped by `retire` commands.
    pub rows_retired: u64,
    /// Applied (non-empty) batches.
    pub batches_applied: u64,
    /// Incremental refreshes run (= non-empty applies).
    pub refreshes: u64,
    /// From-scratch pooled runs: the initial baseline plus one per retire.
    pub primes: u64,
    /// `query` commands answered.
    pub queries: u64,
    /// Queries answered while buffered rows were not yet applied.
    pub stale_queries: u64,
    /// Mean buffered-row count over the answered queries (staleness).
    pub mean_pending_at_query: f64,
    /// Worst-case buffered-row count at any query.
    pub max_pending_at_query: u64,
    /// Total wholesale subtree re-runs across all refreshes (the O(log k)
    /// work the service pays instead of from-scratch runs).
    pub subtrees_recomputed: u64,
    /// Wall-clock spent inside incremental refreshes.
    pub refresh_wall_secs: f64,
    /// Wall-clock spent inside from-scratch primes.
    pub prime_wall_secs: f64,
    /// Whole-session wall-clock.
    pub total_wall_secs: f64,
    /// Ingest throughput over the whole session.
    pub rows_per_sec: f64,
    /// The k-CV estimate when the stream ended (post final flush).
    pub estimate: f64,
}

/// Everything the serve loop mutates, so the command handlers stay small.
struct ServeState<'a> {
    exe: TreeCvExecutor,
    learner: DynLearner<'a>,
    data: Dataset,
    folded: FoldedDataset,
    session: RefreshSession<DynLearner<'a>>,
    pend_x: Vec<f32>,
    pend_y: Vec<f32>,
    estimate: f64,
    rows_ingested: u64,
    rows_retired: u64,
    batches_applied: u64,
    refreshes: u64,
    primes: u64,
    queries: u64,
    stale_queries: u64,
    pending_at_query: RunningStats,
    max_pending: u64,
    subtrees: u64,
    refresh_wall: Duration,
    prime_wall: Duration,
    /// `--engine approx`: stale queries answer a one-step-corrected quick
    /// estimate over window + pending instead of the last refresh alone.
    approx: bool,
}

impl ServeState<'_> {
    /// Fold the buffered rows into the window and refresh the estimate.
    /// Returns `(rows, touched_folds, subtrees_recomputed)`; `(0, 0, 0)`
    /// when nothing was buffered.
    fn apply(&mut self) -> (usize, usize, u64) {
        let rows = self.pend_y.len();
        if rows == 0 {
            return (0, 0, 0);
        }
        self.data.push_rows(&self.pend_x, &self.pend_y);
        let delta = self.folded.append_rows(&self.pend_x, &self.pend_y);
        self.pend_x.clear();
        self.pend_y.clear();
        let res =
            self.exe.refresh(&mut self.session, &self.learner, &self.data, &self.folded, &delta);
        self.estimate = res.estimate;
        self.refresh_wall += res.wall;
        self.subtrees += res.ops.subtrees_recomputed;
        self.refreshes += 1;
        self.batches_applied += 1;
        (rows, delta.touched.len(), res.ops.subtrees_recomputed)
    }

    /// From-scratch pooled baseline (startup and after every retire).
    fn prime(&mut self) {
        let (session, res) = self.exe.prime(&self.learner, &self.data, &self.folded);
        self.session = session;
        self.estimate = res.estimate;
        self.prime_wall += res.wall;
        self.primes += 1;
    }

    /// Approx-engine quick estimate for a stale query: clone the window,
    /// append the pending buffer (the window itself stays untouched — the
    /// refresh engine still owns it), and run the one-step-correction
    /// engine over a fresh assignment of the combined rows. Sequential
    /// and O(n + k·correction) — bounded staleness without paying a
    /// refresh on the query path.
    fn quick_estimate(&self) -> f64 {
        let mut combined = self.data.clone();
        combined.push_rows(&self.pend_x, &self.pend_y);
        let folds = Folds::new(combined.n, self.folded.folds().k(), self.exe.seed);
        ApproxCv::new(self.exe.ordering, self.exe.seed)
            .run(&self.learner, &combined, &folds)
            .estimate
    }

    /// Slide the window: drop the `count` oldest rows, renumber, and
    /// re-prime. Rejects (with a protocol-level message, never a panic)
    /// any retire that would empty the window or a fold chunk.
    fn retire(&mut self, count: usize) -> std::result::Result<(), String> {
        if count == 0 {
            return Ok(());
        }
        let Ok(cutoff) = u32::try_from(count) else {
            return Err(format!("retire count {count} out of range"));
        };
        if count >= self.data.n {
            return Err(format!("retire {count} would empty the window (n = {})", self.data.n));
        }
        if !self.folded.folds().can_retire_below(cutoff) {
            return Err(format!(
                "retire {count} would empty a fold chunk (k = {})",
                self.folded.folds().k()
            ));
        }
        self.data.retire_front(count);
        self.folded.retire_oldest(count);
        self.session.invalidate();
        self.rows_retired += count as u64;
        self.prime();
        Ok(())
    }
}

/// Run the streaming service: read the line protocol from `input`, write
/// replies to `out`, return the session's final metrics. `batch` is the
/// auto-apply threshold (rows buffered before a refresh fires); the
/// config contributes task, initial window (`n`), fold count (single
/// `ks` entry), strategy, ordering, seed and threads.
pub fn run_serve<R: BufRead, W: Write>(
    cfg: &ExperimentConfig,
    batch: usize,
    input: R,
    out: &mut W,
) -> Result<ServeReport> {
    if batch == 0 {
        bail!("serve needs --batch >= 1 (rows per refresh)");
    }
    if cfg.ks.len() != 1 {
        bail!("serve uses a single fold count; got ks = {:?}", cfg.ks);
    }
    let timer = Timer::start();
    let data = build_dataset(cfg)?;
    let k = resolve_single_k(cfg, &data)?;
    let learner_box = (registry::entry(cfg.task).build)(cfg, &data)?;
    let learner = DynLearner(&*learner_box);
    let approx = cfg.engine == Engine::Approx;
    if approx && !learner_box.correctable() {
        bail!(
            "serve --engine approx requires a learner with a one-step held-out correction \
             (ConvexCorrectable), which task `{}` does not provide — drop --engine approx or \
             use a convex task (pegasos, lsqsgd, ridge)",
            cfg.task.name()
        );
    }
    let folds = Folds::new(data.n, k, cfg.seed);
    let folded = FoldedDataset::build(&data, &folds);
    let d = data.d;
    let mut exe = TreeCvExecutor::with_threads_knob(
        Strategy::from(cfg.strategy),
        Ordering::from(cfg.ordering),
        cfg.threads,
    );
    exe.seed = cfg.seed;
    let threads = exe.threads;

    let mut st = ServeState {
        exe,
        learner,
        data,
        folded,
        session: RefreshSession::new(),
        pend_x: Vec::new(),
        pend_y: Vec::new(),
        estimate: 0.0,
        rows_ingested: 0,
        rows_retired: 0,
        batches_applied: 0,
        refreshes: 0,
        primes: 0,
        queries: 0,
        stale_queries: 0,
        pending_at_query: RunningStats::default(),
        max_pending: 0,
        subtrees: 0,
        refresh_wall: Duration::ZERO,
        prime_wall: Duration::ZERO,
        approx,
    };
    st.prime();

    for line in input.lines() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = trimmed.split_whitespace().collect();
        match parts[0] {
            "row" => {
                if parts.len() != d + 2 {
                    writeln!(out, "err row wants y plus {d} features, got {}", parts.len() - 1)?;
                    continue;
                }
                let mut vals = Vec::with_capacity(d + 1);
                for p in &parts[1..] {
                    match p.parse::<f32>() {
                        Ok(v) => vals.push(v),
                        Err(_) => break,
                    }
                }
                if vals.len() != d + 1 {
                    writeln!(out, "err row has an unparsable number: {trimmed}")?;
                    continue;
                }
                st.pend_y.push(vals[0]);
                st.pend_x.extend_from_slice(&vals[1..]);
                st.rows_ingested += 1;
                if st.pend_y.len() >= batch {
                    let (r, t, s) = st.apply();
                    writeln!(
                        out,
                        "applied rows={r} touched={t} subtrees={s} estimate={:.6}",
                        st.estimate
                    )?;
                }
            }
            "flush" => {
                let (r, t, s) = st.apply();
                writeln!(
                    out,
                    "applied rows={r} touched={t} subtrees={s} estimate={:.6}",
                    st.estimate
                )?;
            }
            "query" => {
                let pending = st.pend_y.len() as u64;
                st.queries += 1;
                if pending > 0 {
                    st.stale_queries += 1;
                }
                st.pending_at_query.push(pending as f64);
                st.max_pending = st.max_pending.max(pending);
                let est =
                    if st.approx && pending > 0 { st.quick_estimate() } else { st.estimate };
                writeln!(out, "estimate {est:.6} pending {pending}")?;
            }
            "retire" => match parts.get(1).and_then(|p| p.parse::<usize>().ok()) {
                None => writeln!(out, "err retire wants a row count")?,
                Some(count) => {
                    let (r, t, s) = st.apply();
                    if r > 0 {
                        writeln!(
                            out,
                            "applied rows={r} touched={t} subtrees={s} estimate={:.6}",
                            st.estimate
                        )?;
                    }
                    match st.retire(count) {
                        Ok(()) => writeln!(
                            out,
                            "retired {count} n={} estimate={:.6}",
                            st.data.n, st.estimate
                        )?,
                        Err(msg) => writeln!(out, "err {msg}")?,
                    }
                }
            },
            "stats" => writeln!(
                out,
                "stats n={} ingested={} retired={} batches={} refreshes={} primes={} \
                 queries={} stale={} pending={} cached_nodes={} subtrees={}",
                st.data.n,
                st.rows_ingested,
                st.rows_retired,
                st.batches_applied,
                st.refreshes,
                st.primes,
                st.queries,
                st.stale_queries,
                st.pend_y.len(),
                st.session.cached_nodes(),
                st.subtrees,
            )?,
            "quit" | "exit" => break,
            other => writeln!(out, "err unknown command `{other}`")?,
        }
    }
    // EOF (or quit): fold any still-buffered rows so the reported
    // estimate covers everything the stream delivered.
    let (r, t, s) = st.apply();
    if r > 0 {
        writeln!(out, "applied rows={r} touched={t} subtrees={s} estimate={:.6}", st.estimate)?;
    }

    let total = timer.elapsed().as_secs_f64();
    Ok(ServeReport {
        task: cfg.task,
        k,
        n_final: st.data.n,
        threads,
        rows_ingested: st.rows_ingested,
        rows_retired: st.rows_retired,
        batches_applied: st.batches_applied,
        refreshes: st.refreshes,
        primes: st.primes,
        queries: st.queries,
        stale_queries: st.stale_queries,
        mean_pending_at_query: st.pending_at_query.mean(),
        max_pending_at_query: st.max_pending,
        subtrees_recomputed: st.subtrees,
        refresh_wall_secs: st.refresh_wall.as_secs_f64(),
        prime_wall_secs: st.prime_wall.as_secs_f64(),
        total_wall_secs: total,
        rows_per_sec: if total > 0.0 { st.rows_ingested as f64 / total } else { 0.0 },
        estimate: st.estimate,
    })
}

/// Pretty-print a serve session's final metrics (the `serve` CLI's
/// default output; the schema is documented in EXPERIMENTS.md).
pub fn format_serve_table(report: &ServeReport) -> String {
    let mut s = format!(
        "serve task={} k={} n_final={} threads={} total_wall={:.4}s\n",
        report.task.name(),
        report.k,
        report.n_final,
        report.threads,
        report.total_wall_secs,
    );
    s.push_str(&format!(
        "ingest: rows={} retired={} batches={} rows_per_sec={:.1}\n",
        report.rows_ingested, report.rows_retired, report.batches_applied, report.rows_per_sec,
    ));
    s.push_str(&format!(
        "refresh: refreshes={} primes={} subtrees_recomputed={} refresh_wall={:.4}s \
         prime_wall={:.4}s\n",
        report.refreshes,
        report.primes,
        report.subtrees_recomputed,
        report.refresh_wall_secs,
        report.prime_wall_secs,
    ));
    s.push_str(&format!(
        "queries: total={} stale={} mean_pending={:.2} max_pending={}\n",
        report.queries,
        report.stale_queries,
        report.mean_pending_at_query,
        report.max_pending_at_query,
    ));
    s.push_str(&format!("estimate: {:.6}\n", report.estimate));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Task;
    use crate::cv::treecv::TreeCv;
    use crate::learner::multiset::MultisetLearner;
    use std::io::Cursor;

    fn serve_cfg() -> ExperimentConfig {
        ExperimentConfig {
            task: Task::Multiset,
            n: 40,
            ks: vec![4],
            seed: 9,
            threads: 1,
            ..ExperimentConfig::default()
        }
    }

    fn run(script: &str, batch: usize) -> (ServeReport, String) {
        let cfg = serve_cfg();
        let mut out = Vec::new();
        let report = run_serve(&cfg, batch, Cursor::new(script.to_string()), &mut out)
            .expect("serve session");
        (report, String::from_utf8(out).expect("utf8 output"))
    }

    #[test]
    fn rows_auto_apply_at_batch_size_and_queries_track_staleness() {
        let script = "\
row 0.5 1.0\n\
query\n\
row -0.5 2.0\n\
query\n\
stats\n\
quit\n";
        let (report, out) = run(script, 2);
        // First query sees 1 buffered row (stale), second sees 0 (the
        // second row triggered the batch-of-2 apply).
        assert_eq!(report.queries, 2);
        assert_eq!(report.stale_queries, 1);
        assert_eq!(report.max_pending_at_query, 1);
        assert_eq!(report.batches_applied, 1);
        assert_eq!(report.refreshes, 1);
        assert_eq!(report.primes, 1);
        assert_eq!(report.rows_ingested, 2);
        assert_eq!(report.n_final, 42);
        assert!(report.subtrees_recomputed > 0);
        assert!(out.contains("applied rows=2"));
        assert!(out.contains("pending 1"));
        assert!(out.contains("pending 0"));
        assert!(out.contains("stats n=42"));
    }

    #[test]
    fn served_estimate_matches_library_replay() {
        let script = "\
row 0.25 1.5\n\
row 0.75 -2.0\n\
row -0.25 0.5\n\
flush\n\
quit\n";
        let (report, _) = run(script, 100);
        // Replay the stream through the library directly: same config,
        // same fold seed, same appends — the served estimate must match
        // a from-scratch folded run on the final window bitwise.
        let cfg = serve_cfg();
        let mut data = build_dataset(&cfg).expect("dataset");
        let folds = Folds::new(data.n, 4, cfg.seed);
        let mut folded = FoldedDataset::build(&data, &folds);
        let x = [1.5f32, -2.0, 0.5];
        let y = [0.25f32, 0.75, -0.25];
        data.push_rows(&x, &y);
        folded.append_rows(&x, &y);
        let learner = MultisetLearner::new(data.d);
        let want = TreeCv::default().run_folded(&learner, &data, &folded);
        assert_eq!(report.estimate, want.estimate);
        assert_eq!(report.n_final, data.n);
    }

    #[test]
    fn retire_slides_the_window_and_reprimes() {
        let script = "\
row 0.5 1.0\n\
row 0.5 2.0\n\
row 0.5 3.0\n\
row 0.5 4.0\n\
retire 4\n\
stats\n\
quit\n";
        let (report, out) = run(script, 100);
        assert_eq!(report.rows_retired, 4);
        assert_eq!(report.primes, 2, "baseline + post-retire re-prime");
        assert_eq!(report.n_final, 40, "4 in, 4 out");
        assert!(out.contains("retired 4 n=40"));
    }

    #[test]
    fn approx_engine_folds_pending_rows_into_stale_queries() {
        let cfg = ExperimentConfig {
            task: Task::Ridge,
            engine: Engine::Approx,
            n: 40,
            ks: vec![4],
            seed: 9,
            threads: 1,
            lambda: Some(1.0),
            ..ExperimentConfig::default()
        };
        // One ridge row (d = 90 features), queried before any flush.
        let mut row = String::from("row 0.5");
        for j in 0..90 {
            row.push_str(&format!(" {}", 0.01 * (j as f32 + 1.0)));
        }
        let script = format!("{row}\nquery\nflush\nquery\nquit\n");
        let mut out = Vec::new();
        let report = run_serve(&cfg, 100, Cursor::new(script), &mut out)
            .expect("approx serve session");
        let out = String::from_utf8(out).expect("utf8 output");
        assert_eq!(report.stale_queries, 1);

        // The stale query's reply is the one-step-corrected estimate over
        // window + pending (independently recomputed here), not the
        // baseline estimate of the 40-row window.
        let data = build_dataset(&cfg).expect("dataset");
        let mut combined = data.clone();
        let (y, x) = (vec![0.5f32], {
            let mut x = Vec::new();
            for j in 0..90 {
                x.push(0.01 * (j as f32 + 1.0));
            }
            x
        });
        combined.push_rows(&x, &y);
        let learner_box =
            (registry::entry(Task::Ridge).build)(&cfg, &data).expect("ridge learner");
        let learner = DynLearner(&*learner_box);
        let folds = Folds::new(combined.n, 4, cfg.seed);
        let quick = ApproxCv::new(Ordering::Fixed, cfg.seed)
            .run(&learner, &combined, &folds)
            .estimate;
        assert!(out.contains(&format!("estimate {quick:.6} pending 1")), "{out}");
        // Post-flush queries answer the refreshed exact estimate again.
        assert!(out.contains(&format!("estimate {:.6} pending 0", report.estimate)), "{out}");
    }

    #[test]
    fn approx_engine_rejects_non_correctable_serve_task() {
        let cfg =
            ExperimentConfig { engine: Engine::Approx, ..serve_cfg() };
        let mut out = Vec::new();
        let err = run_serve(&cfg, 32, Cursor::new("quit\n".to_string()), &mut out).unwrap_err();
        assert!(format!("{err}").contains("one-step held-out correction"), "{err}");
    }

    #[test]
    fn bad_input_answers_err_and_keeps_serving() {
        let script = "\
bogus\n\
row 1.0\n\
row 1.0 nope\n\
retire notanumber\n\
retire 1000\n\
query\n\
quit\n";
        let (report, out) = run(script, 100);
        assert_eq!(report.queries, 1, "service survived every bad line");
        assert_eq!(report.rows_ingested, 0);
        assert!(out.contains("err unknown command `bogus`"));
        assert!(out.contains("err row wants y plus 1 features"));
        assert!(out.contains("err row has an unparsable number"));
        assert!(out.contains("err retire wants a row count"));
        assert!(out.contains("err retire 1000 would empty the window"));
    }
}
