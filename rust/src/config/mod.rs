//! Experiment configuration: a small TOML-subset file format with CLI
//! overrides.
//!
//! Every experiment the CLI can run is described by an [`ExperimentConfig`]
//! — task (learner + dataset), CV engine, fold counts, ordering, strategy,
//! repetitions, seeds, dataset sizes — so runs are reproducible from a
//! checked-in file. The parser ([`kv`]) is in-tree (no external TOML crate
//! in this offline environment) and supports the subset the configs need:
//! `key = value` lines with strings, integers, floats, booleans and flat
//! arrays, plus `#` comments.

pub mod kv;

use crate::Result;
use anyhow::{bail, Context};
use std::path::Path;

/// Which (learner, dataset, loss) triple to run — the paper's two
/// experimental tasks plus every other incremental learner this library
/// ships. Each variant has exactly one entry in the coordinator's learner
/// registry (`coordinator::registry`), which holds the dataset family,
/// the constructor-from-config closure, merge support and the sweepable
/// hyperparameter; a registry test pins the bijection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// PEGASOS on covertype-like data, misclassification loss (Table 2 top).
    Pegasos,
    /// LSQSGD on yearmsd-like data, squared loss (Table 2 bottom).
    Lsqsgd,
    /// Online K-means on Gaussian blobs, quantization loss (Table 1 row 3).
    Kmeans,
    /// Histogram density on a 1-D mixture, NLL (Table 1 row 4).
    Density,
    /// Gaussian naive Bayes on covertype-like data (mergeable baseline).
    NaiveBayes,
    /// Online ridge on yearmsd-like data (exact-LOOCV comparator).
    Ridge,
    /// k-NN classification on covertype-like data (Mullin & Sukthankar
    /// exactness oracle with real predictions).
    Knn,
    /// Online perceptron on covertype-like data (sparse save/revert logs).
    Perceptron,
    /// Multiset structural oracle: records training multisets; its "loss"
    /// is a deterministic hash fingerprint in [0, 1), a correctness probe
    /// with no statistical meaning (so it cannot be ranked in `select`).
    Multiset,
    /// PEGASOS through the AOT XLA artifacts (needs the PJRT runtime).
    XlaPegasos,
    /// LSQSGD through the AOT XLA artifacts (needs the PJRT runtime).
    XlaLsqSgd,
}

impl Task {
    pub fn all() -> &'static [Task] {
        &[
            Task::Pegasos,
            Task::Lsqsgd,
            Task::Kmeans,
            Task::Density,
            Task::NaiveBayes,
            Task::Ridge,
            Task::Knn,
            Task::Perceptron,
            Task::Multiset,
            Task::XlaPegasos,
            Task::XlaLsqSgd,
        ]
    }

    pub fn parse(s: &str) -> Result<Task> {
        Ok(match s {
            "pegasos" => Task::Pegasos,
            "lsqsgd" => Task::Lsqsgd,
            "kmeans" => Task::Kmeans,
            "density" => Task::Density,
            "naive_bayes" | "naive-bayes" => Task::NaiveBayes,
            "ridge" => Task::Ridge,
            "knn" => Task::Knn,
            "perceptron" => Task::Perceptron,
            "multiset" => Task::Multiset,
            "xla_pegasos" | "xla-pegasos" => Task::XlaPegasos,
            "xla_lsqsgd" | "xla-lsqsgd" => Task::XlaLsqSgd,
            other => bail!("unknown task `{other}`"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Pegasos => "pegasos",
            Task::Lsqsgd => "lsqsgd",
            Task::Kmeans => "kmeans",
            Task::Density => "density",
            Task::NaiveBayes => "naive_bayes",
            Task::Ridge => "ridge",
            Task::Knn => "knn",
            Task::Perceptron => "perceptron",
            Task::Multiset => "multiset",
            Task::XlaPegasos => "xla_pegasos",
            Task::XlaLsqSgd => "xla_lsqsgd",
        }
    }
}

/// CV engine selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Treecv,
    Standard,
    ParallelTreecv,
    /// Izbicki fold-merging (mergeable learners only).
    Merge,
    /// Approximate CV via one-step corrections ([`crate::cv::approx`]):
    /// train once, correct per fold. Convex correctable learners only
    /// (pegasos, lsqsgd, ridge) — other tasks are a hard error, never a
    /// silent fallback to exact.
    Approx,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Engine> {
        Ok(match s {
            "treecv" => Engine::Treecv,
            "standard" => Engine::Standard,
            // "executor"/"pooled" are aliases: parallel TreeCV runs on the
            // pooled work-stealing executor (cv::executor).
            "parallel_treecv" | "parallel-treecv" | "parallel" | "executor" | "pooled" => {
                Engine::ParallelTreecv
            }
            "merge" => Engine::Merge,
            "approx" => Engine::Approx,
            other => bail!("unknown engine `{other}`"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Treecv => "treecv",
            Engine::Standard => "standard",
            Engine::ParallelTreecv => "parallel_treecv",
            Engine::Merge => "merge",
            Engine::Approx => "approx",
        }
    }
}

/// Feeding-order policy (paper §5 fixed vs randomized).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OrderingCfg {
    Fixed,
    Randomized,
}

impl OrderingCfg {
    pub fn parse(s: &str) -> Result<OrderingCfg> {
        Ok(match s {
            "fixed" => OrderingCfg::Fixed,
            "randomized" => OrderingCfg::Randomized,
            other => bail!("unknown ordering `{other}`"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OrderingCfg::Fixed => "fixed",
            OrderingCfg::Randomized => "randomized",
        }
    }
}

impl From<OrderingCfg> for crate::cv::folds::Ordering {
    fn from(o: OrderingCfg) -> Self {
        match o {
            OrderingCfg::Fixed => crate::cv::folds::Ordering::Fixed,
            OrderingCfg::Randomized => crate::cv::folds::Ordering::Randomized,
        }
    }
}

/// Model-preservation strategy (paper §4.1).
///
/// Honored by the `treecv` and `parallel_treecv` engines (the pooled
/// executor runs SaveRevert with snapshots only at its fork frontier —
/// O(workers) copies per run instead of k − 1). Engines that cannot honor
/// a requested strategy (`standard`, `merge`) reject it with a hard error
/// rather than silently downgrading to Copy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyCfg {
    Copy,
    SaveRevert,
}

impl StrategyCfg {
    pub fn parse(s: &str) -> Result<StrategyCfg> {
        Ok(match s {
            "copy" => StrategyCfg::Copy,
            "save_revert" | "save-revert" => StrategyCfg::SaveRevert,
            other => bail!("unknown strategy `{other}`"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            StrategyCfg::Copy => "copy",
            StrategyCfg::SaveRevert => "save_revert",
        }
    }
}

impl From<StrategyCfg> for crate::cv::Strategy {
    fn from(s: StrategyCfg) -> Self {
        match s {
            StrategyCfg::Copy => crate::cv::Strategy::Copy,
            StrategyCfg::SaveRevert => crate::cv::Strategy::SaveRevert,
        }
    }
}

impl From<crate::cv::Strategy> for StrategyCfg {
    fn from(s: crate::cv::Strategy) -> Self {
        match s {
            crate::cv::Strategy::Copy => StrategyCfg::Copy,
            crate::cv::Strategy::SaveRevert => StrategyCfg::SaveRevert,
        }
    }
}

/// A hyperparameter sweep axis, written `name=v1,v2,...` (the `--sweep`
/// grid syntax; config files may spell it `sweep = "lambda=0.1,0.01"` or
/// as the `sweep_param` + `sweep_values` pair). Which names a task
/// accepts is decided by `coordinator::run_sweep` (pegasos/ridge:
/// `lambda`; lsqsgd: `alpha`).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepGrid {
    pub param: String,
    pub values: Vec<f64>,
}

impl SweepGrid {
    /// Parse the `name=v1,v2,...` grid syntax.
    pub fn parse(s: &str) -> Result<SweepGrid> {
        let Some((param, values)) = s.split_once('=') else {
            bail!("sweep grid `{s}`: expected `name=v1,v2,...` (e.g. lambda=0.1,0.01,0.001)");
        };
        let values = values
            .split(',')
            .map(|p| {
                let p = p.trim();
                p.parse::<f64>().map_err(|e| anyhow::anyhow!("sweep value `{p}`: {e}"))
            })
            .collect::<Result<Vec<f64>>>()?;
        Self::from_values(param.trim(), values)
    }

    /// Build a validated grid from parts (the `sweep_param`/`sweep_values`
    /// config-file form lands here).
    pub fn from_values(param: &str, values: Vec<f64>) -> Result<SweepGrid> {
        if param.is_empty() || !param.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            bail!("sweep grid: bad parameter name `{param}`");
        }
        if values.is_empty() {
            bail!("sweep grid `{param}`: needs at least one value");
        }
        if let Some(v) = values.iter().find(|v| !v.is_finite()) {
            bail!("sweep grid `{param}`: non-finite value {v}");
        }
        Ok(SweepGrid { param: param.to_string(), values })
    }

    /// Render back to the `name=v1,v2,...` syntax (round-trips through
    /// [`Self::parse`]).
    pub fn to_grid_string(&self) -> String {
        let vals: Vec<String> = self.values.iter().map(|v| format!("{v:e}")).collect();
        format!("{}={}", self.param, vals.join(","))
    }
}

/// One hyperparameter override of a [`SelectedLearner`].
#[derive(Debug, Clone, PartialEq)]
pub struct ParamOverride {
    /// Hyperparameter name, e.g. `lambda`.
    pub name: String,
    /// Parsed value.
    pub value: f64,
    /// The user's original spelling of the value, preserved verbatim in
    /// report labels and round-tripped config text (so `lambda=1.0` is
    /// never rewritten as `lambda=1e0`).
    pub text: String,
}

/// One learner of a model-selection run: a task plus an optional single
/// hyperparameter override, written `task` or `task:param=value` (e.g.
/// `pegasos:lambda=1e-4`). Which parameter names a task accepts is decided
/// by the coordinator's registry (same rule as `--sweep`).
#[derive(Debug, Clone, PartialEq)]
pub struct SelectedLearner {
    pub task: Task,
    /// Optional hyperparameter override.
    pub param: Option<ParamOverride>,
}

impl SelectedLearner {
    /// Display label for report rows: `pegasos(lambda=1e-4)` / `knn`.
    pub fn label(&self) -> String {
        match &self.param {
            Some(p) => format!("{}({}={})", self.task.name(), p.name, p.text),
            None => self.task.name().to_string(),
        }
    }
}

/// The learner axis of a model-selection run (`repro select`): a
/// comma-separated list of [`SelectedLearner`]s, written
/// `task[:param=value],task[:param=value],...` — e.g.
/// `pegasos:lambda=1e-4,naive_bayes,knn,perceptron`.
#[derive(Debug, Clone, PartialEq)]
pub struct SelectList {
    pub entries: Vec<SelectedLearner>,
}

impl SelectList {
    /// Parse the `task[:param=value],...` syntax.
    pub fn parse(s: &str) -> Result<SelectList> {
        let mut entries = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                bail!("select list `{s}`: empty learner entry");
            }
            let (task, param) = match part.split_once(':') {
                None => (Task::parse(part)?, None),
                Some((task, rest)) => {
                    let Some((p, v)) = rest.split_once('=') else {
                        bail!("select entry `{part}`: expected `task:param=value`");
                    };
                    let text = v.trim().to_string();
                    let value: f64 = text
                        .parse()
                        .map_err(|e| anyhow::anyhow!("select entry `{part}`: bad value: {e}"))?;
                    if !value.is_finite() {
                        bail!("select entry `{part}`: non-finite value");
                    }
                    let over = ParamOverride { name: p.trim().to_string(), value, text };
                    (Task::parse(task.trim())?, Some(over))
                }
            };
            entries.push(SelectedLearner { task, param });
        }
        if entries.is_empty() {
            bail!("select list `{s}`: needs at least one learner");
        }
        Ok(SelectList { entries })
    }

    /// Render back to the `task[:param=value],...` syntax (round-trips
    /// through [`Self::parse`]).
    pub fn to_list_string(&self) -> String {
        self.entries
            .iter()
            .map(|e| match &e.param {
                Some(p) => format!("{}:{}={}", e.task.name(), p.name, p.text),
                None => e.task.name().to_string(),
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub task: Task,
    pub engine: Engine,
    pub ordering: OrderingCfg,
    pub strategy: StrategyCfg,
    /// Dataset size.
    pub n: usize,
    /// Fold counts to run; `0` means LOOCV (k = n).
    pub ks: Vec<usize>,
    /// Independent repetitions per (k,) cell.
    pub repetitions: usize,
    /// Master seed.
    pub seed: u64,
    /// Regularizer override for λ-parameterized tasks; `None` means the
    /// task's registry default (pegasos/xla_pegasos: 1e-6; ridge: 1.0).
    pub lambda: Option<f64>,
    /// LSQSGD step size; `0.0` means the paper's n^{-1/2} rule.
    pub alpha: f64,
    /// Optional LIBSVM file to load instead of the synthetic dataset.
    pub data_path: Option<String>,
    /// Output JSON path (None = stdout only).
    pub out: Option<String>,
    /// Hyperparameter grid for the `sweep` subcommand (None elsewhere).
    pub sweep: Option<SweepGrid>,
    /// Learner axis for the `select` subcommand (None elsewhere).
    pub learners: Option<SelectList>,
    /// Worker-pool size for pooled engines; `0` = machine parallelism.
    pub threads: usize,
    /// Race the sweep (`repro sweep --race`): eliminate losing grid
    /// values mid-flight with a sequential sign test instead of running
    /// every cell to completion. `false` is the exhaustive sweep.
    pub race: bool,
    /// Decision rounds of a raced sweep (boundaries at
    /// `⌈repetitions·(j+1)/rounds⌉`).
    pub race_rounds: usize,
    /// Significance level of the race's per-round sign test; `0.0` never
    /// eliminates (the exhaustive sweep, bit for bit).
    pub race_alpha: f64,
    /// Run the exact TreeCV engine alongside each `approx` repetition and
    /// record the largest per-fold deviation in `OpCounts::exact_gap_max`
    /// (`repro cv --engine approx --approx-check`). Ignored by the exact
    /// engines.
    pub approx_check: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            task: Task::Pegasos,
            engine: Engine::Treecv,
            ordering: OrderingCfg::Fixed,
            strategy: StrategyCfg::Copy,
            n: 20_000,
            ks: vec![5, 10, 100],
            repetitions: 20,
            seed: 42,
            lambda: None,
            alpha: 0.0,
            data_path: None,
            out: None,
            sweep: None,
            learners: None,
            threads: 0,
            race: false,
            race_rounds: 4,
            race_alpha: 0.05,
            approx_check: false,
        }
    }
}

impl ExperimentConfig {
    /// Load from a config file (TOML-subset; see [`kv`]).
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse from config text.
    pub fn parse(text: &str) -> Result<Self> {
        let table = kv::parse(text)?;
        let mut cfg = Self::default();
        let mut sweep_str: Option<SweepGrid> = None;
        let mut sweep_param: Option<String> = None;
        let mut sweep_values: Option<Vec<f64>> = None;
        for (key, value) in &table.entries {
            match key.as_str() {
                "task" => cfg.task = Task::parse(value.as_str()?)?,
                "engine" => cfg.engine = Engine::parse(value.as_str()?)?,
                "ordering" => cfg.ordering = OrderingCfg::parse(value.as_str()?)?,
                "strategy" => cfg.strategy = StrategyCfg::parse(value.as_str()?)?,
                "n" => cfg.n = value.as_usize()?,
                "ks" => cfg.ks = value.as_usize_array()?,
                "repetitions" => cfg.repetitions = value.as_usize()?,
                "seed" => cfg.seed = value.as_usize()? as u64,
                "lambda" => cfg.lambda = Some(value.as_f64()?),
                "alpha" => cfg.alpha = value.as_f64()?,
                "threads" => cfg.threads = value.as_usize()?,
                "race" => cfg.race = value.as_bool()?,
                "race_rounds" => cfg.race_rounds = value.as_usize()?,
                "race_alpha" => cfg.race_alpha = value.as_f64()?,
                "approx_check" => cfg.approx_check = value.as_bool()?,
                "sweep" => sweep_str = Some(SweepGrid::parse(value.as_str()?)?),
                "sweep_param" => sweep_param = Some(value.as_str()?.to_string()),
                "sweep_values" => sweep_values = Some(value.as_f64_array()?),
                "learners" => cfg.learners = Some(SelectList::parse(value.as_str()?)?),
                "data_path" => cfg.data_path = Some(value.as_str()?.to_string()),
                "out" => cfg.out = Some(value.as_str()?.to_string()),
                other => bail!("unknown config key `{other}`"),
            }
        }
        cfg.sweep = match (sweep_str, sweep_param, sweep_values) {
            (Some(grid), None, None) => Some(grid),
            (None, Some(param), Some(values)) => Some(SweepGrid::from_values(&param, values)?),
            (None, None, None) => None,
            (Some(_), _, _) => {
                bail!("config: give either `sweep` or `sweep_param`+`sweep_values`, not both")
            }
            (None, Some(_), None) => bail!("config: `sweep_param` needs `sweep_values`"),
            (None, None, Some(_)) => bail!("config: `sweep_values` needs `sweep_param`"),
        };
        Ok(cfg)
    }

    /// Serialize to config-file text (round-trip support / `--dump-config`).
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("task = \"{}\"\n", self.task.name()));
        s.push_str(&format!("engine = \"{}\"\n", self.engine.name()));
        s.push_str(&format!("ordering = \"{}\"\n", self.ordering.name()));
        s.push_str(&format!("strategy = \"{}\"\n", self.strategy.name()));
        s.push_str(&format!("n = {}\n", self.n));
        s.push_str(&format!(
            "ks = [{}]\n",
            self.ks.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(", ")
        ));
        s.push_str(&format!("repetitions = {}\n", self.repetitions));
        s.push_str(&format!("seed = {}\n", self.seed));
        if let Some(l) = self.lambda {
            s.push_str(&format!("lambda = {l:e}\n"));
        }
        s.push_str(&format!("alpha = {}\n", self.alpha));
        if self.threads != 0 {
            s.push_str(&format!("threads = {}\n", self.threads));
        }
        // Racing knobs are emitted only off their defaults, so existing
        // dumped configs are byte-stable.
        if self.race {
            s.push_str("race = true\n");
        }
        if self.race_rounds != 4 {
            s.push_str(&format!("race_rounds = {}\n", self.race_rounds));
        }
        if self.race_alpha != 0.05 {
            s.push_str(&format!("race_alpha = {}\n", self.race_alpha));
        }
        if self.approx_check {
            s.push_str("approx_check = true\n");
        }
        if let Some(g) = &self.sweep {
            s.push_str(&format!("sweep = \"{}\"\n", g.to_grid_string()));
        }
        if let Some(l) = &self.learners {
            s.push_str(&format!("learners = \"{}\"\n", l.to_list_string()));
        }
        if let Some(p) = &self.data_path {
            s.push_str(&format!("data_path = \"{p}\"\n"));
        }
        if let Some(p) = &self.out {
            s.push_str(&format!("out = \"{p}\"\n"));
        }
        s
    }

    /// Effective LSQSGD step size for a training-set size.
    pub fn effective_alpha(&self, train_n: usize) -> f64 {
        if self.alpha > 0.0 {
            self.alpha
        } else {
            1.0 / (train_n as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_text() {
        let cfg = ExperimentConfig::default();
        let text = cfg.to_text();
        let back = ExperimentConfig::parse(&text).unwrap();
        assert_eq!(back.n, cfg.n);
        assert_eq!(back.ks, cfg.ks);
        assert_eq!(back.task, cfg.task);
        assert_eq!(back.lambda, cfg.lambda);
    }

    #[test]
    fn partial_config_fills_defaults() {
        let cfg = ExperimentConfig::parse("task = \"lsqsgd\"\nn = 500\nks = [0]\n").unwrap();
        assert_eq!(cfg.task, Task::Lsqsgd);
        assert_eq!(cfg.n, 500);
        assert_eq!(cfg.ks, vec![0]);
        assert_eq!(cfg.repetitions, ExperimentConfig::default().repetitions);
    }

    #[test]
    fn alpha_rule() {
        let cfg = ExperimentConfig { alpha: 0.0, ..Default::default() };
        assert!((cfg.effective_alpha(10_000) - 0.01).abs() < 1e-12);
        let cfg = ExperimentConfig { alpha: 0.5, ..Default::default() };
        assert_eq!(cfg.effective_alpha(10_000), 0.5);
    }

    #[test]
    fn rejects_bad_task_and_key() {
        assert!(ExperimentConfig::parse("task = \"nope\"\n").is_err());
        assert!(ExperimentConfig::parse("wat = 3\n").is_err());
    }

    #[test]
    fn sweep_grid_parses_and_roundtrips() {
        let g = SweepGrid::parse("lambda=0.1, 0.01,1e-3").unwrap();
        assert_eq!(g.param, "lambda");
        assert_eq!(g.values, vec![0.1, 0.01, 1e-3]);
        let back = SweepGrid::parse(&g.to_grid_string()).unwrap();
        assert_eq!(back, g);
        assert_eq!(SweepGrid::parse("alpha=2").unwrap().values, vec![2.0]);
    }

    #[test]
    fn sweep_grid_rejects_malformed() {
        for bad in ["lambda", "lambda=", "lambda=a,b", "=0.1", "la mbda=0.1", "lambda=0.1,,"] {
            assert!(SweepGrid::parse(bad).is_err(), "{bad}");
        }
        assert!(SweepGrid::from_values("lambda", vec![]).is_err());
        assert!(SweepGrid::from_values("lambda", vec![f64::NAN]).is_err());
        assert!(SweepGrid::from_values("lambda", vec![f64::INFINITY]).is_err());
    }

    #[test]
    fn sweep_config_keys_both_forms() {
        let a = ExperimentConfig::parse("sweep = \"lambda=0.1,0.01\"\nthreads = 3\n").unwrap();
        let b =
            ExperimentConfig::parse("sweep_param = \"lambda\"\nsweep_values = [0.1, 0.01]\n")
                .unwrap();
        assert_eq!(a.sweep, b.sweep);
        assert_eq!(a.threads, 3);
        assert_eq!(b.threads, 0);
        // Round-trip through to_text keeps the grid.
        let back = ExperimentConfig::parse(&a.to_text()).unwrap();
        assert_eq!(back.sweep, a.sweep);
        assert_eq!(back.threads, 3);
        // Mixing both forms, or half of the pair, is an error.
        assert!(ExperimentConfig::parse(
            "sweep = \"lambda=0.1\"\nsweep_param = \"lambda\"\nsweep_values = [0.1]\n"
        )
        .is_err());
        assert!(ExperimentConfig::parse("sweep_param = \"lambda\"\n").is_err());
        assert!(ExperimentConfig::parse("sweep_values = [0.1]\n").is_err());
    }

    #[test]
    fn race_keys_parse_default_and_roundtrip() {
        // Defaults: racing off, 4 rounds, alpha 0.05.
        let cfg = ExperimentConfig::parse("task = \"pegasos\"\n").unwrap();
        assert!(!cfg.race);
        assert_eq!(cfg.race_rounds, 4);
        assert_eq!(cfg.race_alpha, 0.05);
        // Defaults are not emitted, so pre-racing dumped configs are
        // byte-stable.
        assert!(!cfg.to_text().contains("race"));

        let cfg = ExperimentConfig::parse(
            "race = true\nrace_rounds = 6\nrace_alpha = 0.01\nsweep = \"lambda=0.1,0.01\"\n",
        )
        .unwrap();
        assert!(cfg.race);
        assert_eq!(cfg.race_rounds, 6);
        assert_eq!(cfg.race_alpha, 0.01);
        let back = ExperimentConfig::parse(&cfg.to_text()).unwrap();
        assert!(back.race);
        assert_eq!(back.race_rounds, 6);
        assert_eq!(back.race_alpha, 0.01);
        assert_eq!(back.sweep, cfg.sweep);
        // Type errors are hard errors.
        assert!(ExperimentConfig::parse("race = 1\n").is_err());
        assert!(ExperimentConfig::parse("race_rounds = \"many\"\n").is_err());
        assert!(ExperimentConfig::parse("race_alpha = \"low\"\n").is_err());
    }

    #[test]
    fn parses_every_enum() {
        // Every Task variant's canonical name round-trips through parse.
        for &t in Task::all() {
            assert_eq!(Task::parse(t.name()).unwrap(), t, "{t:?}");
        }
        assert_eq!(Task::all().len(), 11);
        let engines =
            ["treecv", "standard", "parallel_treecv", "executor", "pooled", "merge", "approx"];
        for e in engines {
            assert!(Engine::parse(e).is_ok(), "{e}");
        }
        assert_eq!(Engine::parse("executor").unwrap(), Engine::ParallelTreecv);
        assert_eq!(Engine::parse("approx").unwrap(), Engine::Approx);
        assert_eq!(Engine::Approx.name(), "approx");
    }

    #[test]
    fn approx_check_key_parses_defaults_and_roundtrips() {
        let cfg = ExperimentConfig::parse("task = \"ridge\"\n").unwrap();
        assert!(!cfg.approx_check);
        // Off-by-default knobs are not emitted: dumped configs stay
        // byte-stable.
        assert!(!cfg.to_text().contains("approx_check"));
        let cfg =
            ExperimentConfig::parse("engine = \"approx\"\napprox_check = true\n").unwrap();
        assert_eq!(cfg.engine, Engine::Approx);
        assert!(cfg.approx_check);
        let back = ExperimentConfig::parse(&cfg.to_text()).unwrap();
        assert_eq!(back.engine, Engine::Approx);
        assert!(back.approx_check);
        assert!(ExperimentConfig::parse("approx_check = 1\n").is_err());
    }

    #[test]
    fn select_list_parses_and_roundtrips() {
        let l = SelectList::parse("pegasos:lambda=1e-4, naive_bayes,knn").unwrap();
        assert_eq!(l.entries.len(), 3);
        assert_eq!(l.entries[0].task, Task::Pegasos);
        let p = l.entries[0].param.as_ref().unwrap();
        assert_eq!((p.name.as_str(), p.value, p.text.as_str()), ("lambda", 1e-4, "1e-4"));
        assert_eq!(l.entries[1].task, Task::NaiveBayes);
        assert_eq!(l.entries[1].param, None);
        assert_eq!(l.entries[0].label(), "pegasos(lambda=1e-4)");
        assert_eq!(l.entries[2].label(), "knn");
        let back = SelectList::parse(&l.to_list_string()).unwrap();
        assert_eq!(back, l);
        // The user's value spelling is preserved, never re-rendered.
        let l = SelectList::parse("ridge:lambda=1.0,lsqsgd").unwrap();
        assert_eq!(l.entries[0].label(), "ridge(lambda=1.0)");
        assert_eq!(l.to_list_string(), "ridge:lambda=1.0,lsqsgd");
    }

    #[test]
    fn select_list_rejects_malformed() {
        let bads = ["", "pegasos:", "pegasos:lambda", "pegasos:lambda=x", "nope", "pegasos,,knn"];
        for bad in bads {
            assert!(SelectList::parse(bad).is_err(), "{bad}");
        }
        assert!(SelectList::parse("pegasos:lambda=inf").is_err());
    }

    #[test]
    fn learners_config_key_roundtrips() {
        let cfg =
            ExperimentConfig::parse("learners = \"pegasos:lambda=1e-3,knn,perceptron\"\n")
                .unwrap();
        let l = cfg.learners.as_ref().unwrap();
        assert_eq!(l.entries.len(), 3);
        let back = ExperimentConfig::parse(&cfg.to_text()).unwrap();
        assert_eq!(back.learners, cfg.learners);
    }
}
