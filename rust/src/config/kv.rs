//! TOML-subset parser for config files (`key = value` lines).
//!
//! Supported values: double-quoted strings (with `\"`, `\\`, `\n`, `\t`
//! escapes), integers, floats (including scientific notation), booleans,
//! and flat arrays of the above. `#` starts a comment; blank lines are
//! skipped. No tables/nesting — the experiment configs are flat.

use crate::Result;
use anyhow::bail;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected boolean, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as usize),
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize_array(&self) -> Result<Vec<usize>> {
        match self {
            Value::Array(items) => items.iter().map(|v| v.as_usize()).collect(),
            other => bail!("expected array, got {other:?}"),
        }
    }

    /// Flat numeric array (ints are widened) — the `sweep_values` grid.
    pub fn as_f64_array(&self) -> Result<Vec<f64>> {
        match self {
            Value::Array(items) => items.iter().map(|v| v.as_f64()).collect(),
            other => bail!("expected array, got {other:?}"),
        }
    }
}

/// A parsed file: ordered `(key, value)` pairs.
#[derive(Debug, Default)]
pub struct Table {
    pub entries: Vec<(String, Value)>,
}

impl Table {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
}

/// Parse config text.
pub fn parse(text: &str) -> Result<Table> {
    let mut table = Table::default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`, got `{raw}`", lineno + 1);
        };
        let key = key.trim();
        if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            bail!("line {}: bad key `{key}`", lineno + 1);
        }
        let value = parse_value(value.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        if table.get(key).is_some() {
            bail!("line {}: duplicate key `{key}`", lineno + 1);
        }
        table.entries.push((key.to_string(), value));
    }
    Ok(table)
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => escaped = false,
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            bail!("unterminated string `{s}`");
        };
        return Ok(Value::Str(unescape(inner)?));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            bail!("unterminated array `{s}`");
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>> =
            split_top_level(inner).iter().map(|p| parse_value(p)).collect();
        return Ok(Value::Array(items?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value `{s}`")
}

/// Split array contents on commas (no nested arrays in the subset, but
/// respect quoted strings).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in s.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            ',' if !in_str => {
                parts.push(s[start..i].trim());
                start = i + 1;
            }
            _ => escaped = false,
        }
    }
    parts.push(s[start..].trim());
    parts
}

fn unescape(s: &str) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some(other) => bail!("bad escape `\\{other}`"),
            None => bail!("dangling backslash"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let t = parse("a = 1\nb = -2.5\nc = \"hi\"\nd = true\ne = 1e-6\n").unwrap();
        assert_eq!(t.get("a"), Some(&Value::Int(1)));
        assert_eq!(t.get("b"), Some(&Value::Float(-2.5)));
        assert_eq!(t.get("c"), Some(&Value::Str("hi".into())));
        assert_eq!(t.get("d"), Some(&Value::Bool(true)));
        assert_eq!(t.get("e"), Some(&Value::Float(1e-6)));
    }

    #[test]
    fn parses_arrays() {
        let t = parse("ks = [5, 10, 100]\nempty = []\n").unwrap();
        assert_eq!(t.get("ks").unwrap().as_usize_array().unwrap(), vec![5, 10, 100]);
        assert_eq!(t.get("empty"), Some(&Value::Array(vec![])));
    }

    #[test]
    fn comments_and_blanks() {
        let t = parse("# header\n\na = 1 # trailing\nb = \"x # not a comment\"\n").unwrap();
        assert_eq!(t.get("a"), Some(&Value::Int(1)));
        assert_eq!(t.get("b").unwrap().as_str().unwrap(), "x # not a comment");
    }

    #[test]
    fn string_escapes() {
        let t = parse(r#"s = "a\"b\\c\nd""#).unwrap();
        assert_eq!(t.get("s").unwrap().as_str().unwrap(), "a\"b\\c\nd");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("just words\n").is_err());
        assert!(parse("a = \n").is_err());
        assert!(parse("a = [1, 2\n").is_err());
        assert!(parse("a = \"unterminated\n").is_err());
        assert!(parse("a = 1\na = 2\n").is_err());
        assert!(parse("bad key = 1\n").is_err());
    }

    #[test]
    fn value_accessors_type_check() {
        assert!(Value::Int(-1).as_usize().is_err());
        assert!(Value::Str("x".into()).as_f64().is_err());
        assert!(Value::Int(3).as_f64().is_ok());
        assert!(Value::Int(3).as_str().is_err());
        assert!(Value::Bool(true).as_bool().unwrap());
        assert!(!Value::Bool(false).as_bool().unwrap());
        assert!(Value::Int(1).as_bool().is_err());
        assert!(Value::Str("true".into()).as_bool().is_err());
    }

    #[test]
    fn f64_arrays_widen_ints() {
        let t = parse("vals = [1e-4, 0.5, 2]\nbad = [1, \"x\"]\n").unwrap();
        assert_eq!(t.get("vals").unwrap().as_f64_array().unwrap(), vec![1e-4, 0.5, 2.0]);
        assert!(t.get("bad").unwrap().as_f64_array().is_err());
        assert!(Value::Int(3).as_f64_array().is_err());
    }
}
