//! Miniature models of the executor's three load-bearing concurrency
//! protocols, built on the instrumented shim primitives so the
//! deterministic scheduler can drive them through adversarial
//! interleavings.
//!
//! Each model family carries a *bug* enum: `Correct` is the protocol as
//! the real executor implements it (`cv/executor.rs`), and every other
//! variant seeds one realistic defect. The model-check suite asserts both
//! directions — the correct model survives every explored schedule, and
//! every seeded bug is caught within the schedule budget. The second half
//! is what proves the checker itself is live: a detector that has never
//! seen a failure proves nothing.
//!
//! Explicit [`checkpoint`] calls mark the protocol-step boundaries.
//! Under [`super::sched::Preemption::EveryOp`] they are redundant (every
//! primitive op already yields); under
//! [`super::sched::Preemption::ExplicitOnly`] they define the coarse
//! action granularity that keeps bounded-exhaustive DFS tractable. They
//! are placed exactly at the handshake windows the protocols exist to
//! close (e.g. between a failed sweep and registration), so the seeded
//! bugs stay reachable even at the coarse granularity.

use std::collections::VecDeque;
use std::sync::Arc;

use super::sched::Model;
use super::shim::thread::{self, Thread};
use super::shim::{checkpoint, AtomicBool, AtomicI64, AtomicUsize, Mutex};
use std::sync::atomic::Ordering;

// ---------------------------------------------------------------------------
// Protocol 1a: register-before-sweep park/unpark handshake, faithful
// producer-is-consumer miniature of the executor worker loop.
// ---------------------------------------------------------------------------

/// Seeded defects for [`ParkChainModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParkChainBug {
    Correct,
    /// Skip the done re-check between registering and parking: a worker
    /// that registers after the final `wake_all` drained the parked list
    /// sleeps forever.
    SkipDoneRecheck,
    /// Issue the completion `wake_all` *before* storing the done flag: a
    /// woken worker can re-park before the flag is visible, after the
    /// waker has already drained the list.
    WakeThenStore,
}

/// A k-task serial chain (task i pushes task i+1 — the executor's
/// serial-subtree shape) processed by `workers` symmetric workers using
/// the executor's exact park protocol: sweep → done-check → register →
/// verification sweep → done re-check → park.
///
/// Invariants: every task processed exactly once, and no deadlock (the
/// scheduler reports lost wakeups as [`super::sched::Outcome::Deadlock`]).
pub struct ParkChainModel {
    k: u32,
    workers: usize,
    bug: ParkChainBug,
    queue: Mutex<VecDeque<u32>>,
    processed: Mutex<Vec<u32>>,
    work_done: AtomicUsize,
    done: AtomicBool,
    parked: Mutex<Vec<(usize, Thread)>>,
}

pub fn park_chain(k: u32, workers: usize, bug: ParkChainBug) -> Arc<dyn Model> {
    let mut queue = VecDeque::new();
    queue.push_back(0);
    Arc::new(ParkChainModel {
        k,
        workers,
        bug,
        queue: Mutex::new(queue),
        processed: Mutex::new(vec![0; k as usize]),
        work_done: AtomicUsize::new(0),
        done: AtomicBool::new(false),
        parked: Mutex::new(Vec::new()),
    })
}

impl ParkChainModel {
    fn unregister(&self, wid: usize) {
        self.parked.lock().retain(|(w, _)| *w != wid);
    }

    fn wake_one(&self) {
        let target = self.parked.lock().pop();
        if let Some((_, t)) = target {
            t.unpark();
        }
    }

    fn wake_all(&self) {
        let drained = std::mem::take(&mut *self.parked.lock());
        for (_, t) in drained {
            t.unpark();
        }
    }
}

impl Model for ParkChainModel {
    fn n_threads(&self) -> usize {
        self.workers
    }

    fn thread(&self, tid: usize) {
        loop {
            // Sweep.
            let task = self.queue.lock().pop_front();
            if let Some(i) = task {
                checkpoint();
                self.processed.lock()[i as usize] += 1;
                if i + 1 < self.k {
                    self.queue.lock().push_back(i + 1);
                    self.wake_one();
                }
                let d = self.work_done.fetch_add(1, Ordering::AcqRel) + 1;
                if d == self.k as usize {
                    match self.bug {
                        ParkChainBug::WakeThenStore => {
                            self.wake_all();
                            checkpoint();
                            self.done.store(true, Ordering::Release);
                        }
                        _ => {
                            self.done.store(true, Ordering::Release);
                            checkpoint();
                            self.wake_all();
                        }
                    }
                }
                continue;
            }
            if self.done.load(Ordering::Acquire) {
                return;
            }
            // The window the handshake closes: work or done can arrive
            // right here, before we register.
            checkpoint();
            self.parked.lock().push((tid, thread::current()));
            checkpoint();
            // Verification sweep: work pushed before we registered would
            // otherwise be missed along with its wake.
            if !self.queue.lock().is_empty() {
                self.unregister(tid);
                continue;
            }
            checkpoint();
            if self.bug != ParkChainBug::SkipDoneRecheck && self.done.load(Ordering::Acquire) {
                self.unregister(tid);
                continue;
            }
            thread::park();
            self.unregister(tid);
        }
    }

    fn check(&self) -> Result<(), String> {
        let processed = self.processed.lock();
        for (i, &n) in processed.iter().enumerate() {
            if n != 1 {
                return Err(format!("task {i} processed {n} times (want exactly 1)"));
            }
        }
        let d = self.work_done.load(Ordering::Acquire);
        if d != self.k as usize {
            return Err(format!("work_done = {d}, want {}", self.k));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Protocol 1b: the same handshake against an *external* producer that
// stops producing — the lost-wakeup litmus for the ROADMAP's streaming
// direction, where pushers are not consumers and cannot sweep their own
// work back up.
// ---------------------------------------------------------------------------

/// Seeded defects for [`HandoffModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HandoffBug {
    Correct,
    /// Park without the verification sweep after registering: an item
    /// pushed (with its wake lost) just before registration strands the
    /// consumer forever once the producer stops.
    SkipVerifySweep,
    /// Register *after* the sweep instead of before: the push+wake can
    /// land in between, so neither the sweep nor the wake is seen.
    RegisterAfterSweep,
    /// Producer wakes before pushing: the woken consumer re-sweeps,
    /// finds nothing, and re-parks before the item lands.
    WakeBeforePush,
}

/// One producer (tid 0) pushes `k` items then exits; `consumers` workers
/// drain them with the register/verify/park handshake. The consumer that
/// processes the last item wakes all peers so everyone can observe
/// completion.
pub struct HandoffModel {
    k: usize,
    consumers: usize,
    bug: HandoffBug,
    queue: Mutex<VecDeque<u32>>,
    consumed: AtomicUsize,
    parked: Mutex<Vec<(usize, Thread)>>,
}

pub fn handoff(k: usize, consumers: usize, bug: HandoffBug) -> Arc<dyn Model> {
    Arc::new(HandoffModel {
        k,
        consumers,
        bug,
        queue: Mutex::new(VecDeque::new()),
        consumed: AtomicUsize::new(0),
        parked: Mutex::new(Vec::new()),
    })
}

impl HandoffModel {
    fn unregister(&self, wid: usize) {
        self.parked.lock().retain(|(w, _)| *w != wid);
    }

    fn wake_one(&self) {
        let target = self.parked.lock().pop();
        if let Some((_, t)) = target {
            t.unpark();
        }
    }

    fn wake_all(&self) {
        let drained = std::mem::take(&mut *self.parked.lock());
        for (_, t) in drained {
            t.unpark();
        }
    }

    fn producer(&self) {
        for i in 0..self.k {
            if self.bug == HandoffBug::WakeBeforePush {
                self.wake_one();
                checkpoint();
                self.queue.lock().push_back(i as u32);
            } else {
                self.queue.lock().push_back(i as u32);
                checkpoint();
                self.wake_one();
            }
        }
    }

    fn consumer(&self, tid: usize) {
        loop {
            if self.consumed.load(Ordering::Acquire) == self.k {
                return;
            }
            let item = self.queue.lock().pop_front();
            if item.is_some() {
                checkpoint();
                let c = self.consumed.fetch_add(1, Ordering::AcqRel) + 1;
                if c == self.k {
                    self.wake_all();
                }
                continue;
            }
            // The pre-registration window: a push+wake landing here is
            // exactly what the verification sweep must recover.
            checkpoint();
            if self.bug == HandoffBug::RegisterAfterSweep {
                if !self.queue.lock().is_empty() {
                    continue;
                }
                checkpoint();
                self.parked.lock().push((tid, thread::current()));
            } else {
                self.parked.lock().push((tid, thread::current()));
                checkpoint();
                if self.bug != HandoffBug::SkipVerifySweep && !self.queue.lock().is_empty() {
                    self.unregister(tid);
                    continue;
                }
            }
            checkpoint();
            if self.consumed.load(Ordering::Acquire) == self.k {
                self.unregister(tid);
                continue;
            }
            thread::park();
            self.unregister(tid);
        }
    }
}

impl Model for HandoffModel {
    fn n_threads(&self) -> usize {
        1 + self.consumers
    }

    fn thread(&self, tid: usize) {
        if tid == 0 {
            self.producer();
        } else {
            self.consumer(tid);
        }
    }

    fn check(&self) -> Result<(), String> {
        let c = self.consumed.load(Ordering::Acquire);
        if c != self.k {
            return Err(format!("consumed {c} of {} items", self.k));
        }
        let q = self.queue.lock();
        if !q.is_empty() {
            return Err(format!("{} items stranded in queue", q.len()));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Protocol 2: cancellation at pop/fork points — dropped-task accounting
// and snapshot-buffer conservation, mirroring the executor's RunOutcome
// bookkeeping over a binary range tree.
// ---------------------------------------------------------------------------

/// Seeded defects for [`CancelModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelBug {
    Correct,
    /// A leaf that observes cancellation after taking its snapshot buffer
    /// bails out without returning the buffer to the pool.
    LeakSnapshotOnCancel,
    /// A cancelled pop drops the subtree without adding its leaves to the
    /// dropped count — `leaves_done + leaves_dropped` no longer reaches k.
    ForgetDropAccounting,
    /// A cancelled fork pre-accounts the right half as dropped but still
    /// pushes it, so the eventual pop accounts it a second time.
    DoubleAccount,
}

/// `workers` workers process a binary range tree over `k` leaves; a
/// canceller thread (tid 0) fires the token at a scheduler-chosen moment.
/// Leaves take a buffer from a conservation-counted pool while working.
///
/// Invariants: `leaves_done + leaves_dropped == k` exactly (every leaf
/// accounted once, whichever side of the cancel it lands on) and every
/// buffer returned (`outstanding == 0`).
pub struct CancelModel {
    k: u32,
    workers: usize,
    bug: CancelBug,
    cancel: AtomicBool,
    queue: Mutex<Vec<(u32, u32)>>,
    in_flight: AtomicUsize,
    outstanding: AtomicUsize,
    leaves_done: AtomicUsize,
    leaves_dropped: AtomicUsize,
    tasks_dropped: AtomicUsize,
}

pub fn cancel_tree(k: u32, workers: usize, bug: CancelBug) -> Arc<dyn Model> {
    Arc::new(CancelModel {
        k,
        workers,
        bug,
        cancel: AtomicBool::new(false),
        queue: Mutex::new(vec![(0, k)]),
        in_flight: AtomicUsize::new(1),
        outstanding: AtomicUsize::new(0),
        leaves_done: AtomicUsize::new(0),
        leaves_dropped: AtomicUsize::new(0),
        tasks_dropped: AtomicUsize::new(0),
    })
}

impl CancelModel {
    /// Handle one popped range task, recursing left and forking right —
    /// the executor's traversal shape, with its cancel checks at the pop
    /// and fork points.
    fn process(&self, lo: u32, hi: u32) {
        checkpoint();
        if self.cancel.load(Ordering::Acquire) {
            // Pop-point cancellation: drop the whole subtree, accounted.
            if self.bug != CancelBug::ForgetDropAccounting {
                self.leaves_dropped.fetch_add((hi - lo) as usize, Ordering::AcqRel);
            }
            self.tasks_dropped.fetch_add(1, Ordering::AcqRel);
            return;
        }
        if hi - lo == 1 {
            // Leaf: take a snapshot buffer from the pool while working.
            self.outstanding.fetch_add(1, Ordering::AcqRel);
            checkpoint();
            if self.bug == CancelBug::LeakSnapshotOnCancel && self.cancel.load(Ordering::Acquire)
            {
                self.leaves_dropped.fetch_add(1, Ordering::AcqRel);
                return; // buffer never returned
            }
            self.leaves_done.fetch_add(1, Ordering::AcqRel);
            self.outstanding.fetch_sub(1, Ordering::AcqRel);
            return;
        }
        let mid = lo + (hi - lo) / 2;
        if self.bug == CancelBug::DoubleAccount && self.cancel.load(Ordering::Acquire) {
            self.leaves_dropped.fetch_add((hi - mid) as usize, Ordering::AcqRel);
        }
        // Fork: right half to the shared queue, recurse left.
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        self.queue.lock().push((mid, hi));
        self.process(lo, mid);
    }
}

impl Model for CancelModel {
    fn n_threads(&self) -> usize {
        1 + self.workers
    }

    fn thread(&self, tid: usize) {
        if tid == 0 {
            // Canceller: fire the token at a scheduler-chosen moment.
            checkpoint();
            self.cancel.store(true, Ordering::Release);
            return;
        }
        loop {
            if self.in_flight.load(Ordering::Acquire) == 0 {
                return;
            }
            let task = self.queue.lock().pop();
            match task {
                Some((lo, hi)) => {
                    self.process(lo, hi);
                    self.in_flight.fetch_sub(1, Ordering::AcqRel);
                }
                // A peer still owns an in-flight subtree; spin through a
                // decision point until it forks or finishes.
                None => checkpoint(),
            }
        }
    }

    fn check(&self) -> Result<(), String> {
        let done = self.leaves_done.load(Ordering::Acquire);
        let dropped = self.leaves_dropped.load(Ordering::Acquire);
        let outstanding = self.outstanding.load(Ordering::Acquire);
        if outstanding != 0 {
            return Err(format!("{outstanding} snapshot buffers never returned to the pool"));
        }
        if done + dropped != self.k as usize {
            return Err(format!(
                "leaf accounting off: done {done} + dropped {dropped} != k {}",
                self.k
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Protocol 3: priority injector — admission order among equal priorities,
// mirroring the executor's max-by-(priority, oldest-seq) injector pop.
// ---------------------------------------------------------------------------

/// Seeded defects for [`PriorityModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PriorityBug {
    Correct,
    /// Pop newest-first regardless of priority.
    IgnorePriority,
    /// Break priority ties newest-first (LIFO) instead of oldest-first.
    LifoTies,
}

struct Injected {
    seq: u64,
    id: u32,
    run: usize,
}

/// A pre-filled injector of items, each belonging to a run with a live
/// priority cell; `workers` workers drain it concurrently, and (in the
/// dynamic variant) a steerer thread re-prioritizes one run mid-drain,
/// like `RunCtrl::set_priority` on a racing sweep.
///
/// The removal log is recorded under the injector lock, so "admission
/// order" is well defined. Invariants: statically, removals come out
/// exactly sorted by (priority desc, seq asc); dynamically, items of the
/// same run still leave in seq order, whatever the re-prioritization
/// timing.
pub struct PriorityModel {
    workers: usize,
    bug: PriorityBug,
    /// `Some(run, new_priority)` adds a steerer thread (tid 0).
    steer: Option<(usize, i64)>,
    run_priority: Vec<AtomicI64>,
    injector: Mutex<Vec<Injected>>,
    log: Mutex<Vec<u32>>,
    /// Immutable copy of the admitted items for `check`:
    /// (initial priority, seq, id, run).
    spec: Vec<(i64, u64, u32, usize)>,
}

/// Static variant: fixed priorities, full sorted-order invariant.
pub fn priority_static(
    items: &[(i64, u32)], // (priority, id); seq = position
    workers: usize,
    bug: PriorityBug,
) -> Arc<dyn Model> {
    build_priority(items, workers, bug, None)
}

/// Dynamic variant: run 1's priority is bumped mid-drain by a steerer.
pub fn priority_dynamic(
    items: &[(i64, u32)],
    workers: usize,
    bug: PriorityBug,
    bump_to: i64,
) -> Arc<dyn Model> {
    build_priority(items, workers, bug, Some((1, bump_to)))
}

fn build_priority(
    items: &[(i64, u32)],
    workers: usize,
    bug: PriorityBug,
    steer: Option<(usize, i64)>,
) -> Arc<dyn Model> {
    // One run per distinct starting priority, in order of appearance.
    let mut prios: Vec<i64> = Vec::new();
    let mut injector = Vec::new();
    let mut spec = Vec::new();
    for (seq, &(p, id)) in items.iter().enumerate() {
        let run = match prios.iter().position(|&q| q == p) {
            Some(r) => r,
            None => {
                prios.push(p);
                prios.len() - 1
            }
        };
        injector.push(Injected { seq: seq as u64, id, run });
        spec.push((p, seq as u64, id, run));
    }
    Arc::new(PriorityModel {
        workers,
        bug,
        steer,
        run_priority: prios.into_iter().map(AtomicI64::new).collect(),
        injector: Mutex::new(injector),
        log: Mutex::new(Vec::new()),
        spec,
    })
}

impl PriorityModel {
    fn pop(&self) -> bool {
        let mut inj = self.injector.lock();
        if inj.is_empty() {
            return false;
        }
        let key = |it: &Injected| {
            let p = self.run_priority[it.run].load(Ordering::Acquire);
            match self.bug {
                PriorityBug::Correct => (p, std::cmp::Reverse(it.seq)),
                PriorityBug::LifoTies => (p, std::cmp::Reverse(u64::MAX - it.seq)),
                PriorityBug::IgnorePriority => (0, std::cmp::Reverse(u64::MAX - it.seq)),
            }
        };
        let idx = (0..inj.len())
            .max_by_key(|&i| key(&inj[i]))
            // invariant: inj is non-empty, checked above.
            .expect("non-empty injector");
        let it = inj.swap_remove(idx);
        self.log.lock().push(it.id);
        true
    }
}

impl Model for PriorityModel {
    fn n_threads(&self) -> usize {
        usize::from(self.steer.is_some()) + self.workers
    }

    fn thread(&self, tid: usize) {
        if let Some((run, bump)) = self.steer {
            if tid == 0 {
                checkpoint();
                self.run_priority[run].store(bump, Ordering::Release);
                return;
            }
        }
        while self.pop() {
            checkpoint();
        }
    }

    fn check(&self) -> Result<(), String> {
        let log = self.log.lock();
        if log.len() != self.spec.len() {
            return Err(format!("popped {} of {} items", log.len(), self.spec.len()));
        }
        if self.steer.is_none() {
            // Static priorities: each pop takes the max of what remains,
            // so the removal log must be *exactly* the sorted order.
            let mut expected: Vec<(i64, u64, u32)> =
                self.spec.iter().map(|&(p, seq, id, _)| (p, seq, id)).collect();
            expected.sort_by_key(|&(p, seq, _)| (std::cmp::Reverse(p), seq));
            let want: Vec<u32> = expected.iter().map(|&(_, _, id)| id).collect();
            if *log != want {
                return Err(format!("admission order {log:?}, want {want:?}"));
            }
            return Ok(());
        }
        // Dynamic re-prioritization: the global order depends on the
        // steerer's timing, but items of one run always share a priority
        // cell, so within a run the seq tie-break must hold whatever the
        // bump timing — FIFO admission per run.
        for run in 0..self.run_priority.len() {
            let run_ids: Vec<u32> = self
                .spec
                .iter()
                .filter(|&&(_, _, _, r)| r == run)
                .map(|&(_, _, id, _)| id)
                .collect();
            let popped: Vec<u32> =
                log.iter().copied().filter(|id| run_ids.contains(id)).collect();
            if popped != run_ids {
                return Err(format!(
                    "run {run} admitted out of seq order: {popped:?}, want {run_ids:?}"
                ));
            }
        }
        Ok(())
    }
}
