//! Instrumented concurrency primitives for model checking.
//!
//! Every type here has *dual behavior*, keyed on whether the calling OS
//! thread has a scheduler installed (see [`super::sched::current_sched`]):
//!
//! - **Scheduled** (inside a vthread of [`super::sched::run_schedule`]):
//!   each operation is a potential decision point, and blocking behavior
//!   (mutex contention, condvar waits, park) is *virtualized* — the
//!   scheduler decides who proceeds, so interleavings are fully
//!   deterministic and replayable.
//! - **Passthrough** (no scheduler): the operation delegates to the raw
//!   `std` primitive with identical semantics. This is what makes a
//!   `--cfg treecv_model_check` build safe to run the entire ordinary
//!   test suite: code compiled against these types behaves like `std`
//!   unless a schedule is actively driving it.
//!
//! One hard rule keeps abort handling sound: once a thread is already
//! unwinding (`std::thread::panicking()`), shim operations never consult
//! the scheduler and never throw the [`super::sched::ScheduleAborted`]
//! sentinel — they perform the raw operation (or no-op, for `park`).
//! Executor cleanup paths (e.g. its panic-signal `Drop`) run during
//! unwinding; a sentinel panic there would be a double panic and abort
//! the whole process.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::sched::{clear_current, current_sched, set_current, SchedInner};

/// Scheduler handle for the current op, or `None` for passthrough /
/// mid-unwind bypass.
fn op_sched() -> Option<(Arc<SchedInner>, usize)> {
    if std::thread::panicking() {
        return None;
    }
    current_sched()
}

/// Decision point before (potentially) touching shared state. No-op in
/// passthrough mode and in `Preemption::ExplicitOnly` schedules.
fn sync_point() {
    if let Some((s, me)) = op_sched() {
        s.maybe_yield(me);
    }
}

/// An *explicit* decision point — yields to the scheduler in every
/// preemption mode. Models call this to mark the coarse action
/// boundaries that bounded-exhaustive DFS interleaves. Free (a
/// thread-local read) when no schedule is active.
pub fn checkpoint() {
    if let Some((s, me)) = op_sched() {
        s.yield_decision(me);
    }
}

macro_rules! atomic_shim {
    ($name:ident, $raw:ty, $t:ty) => {
        /// Instrumented atomic: raw `std` value semantics, with each op a
        /// scheduler decision point when a schedule is active.
        #[derive(Debug, Default)]
        pub struct $name($raw);

        impl $name {
            pub const fn new(v: $t) -> Self {
                Self(<$raw>::new(v))
            }

            pub fn load(&self, order: Ordering) -> $t {
                sync_point();
                self.0.load(order)
            }

            pub fn store(&self, v: $t, order: Ordering) {
                sync_point();
                self.0.store(v, order)
            }

            pub fn swap(&self, v: $t, order: Ordering) -> $t {
                sync_point();
                self.0.swap(v, order)
            }

            pub fn compare_exchange(
                &self,
                current: $t,
                new: $t,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$t, $t> {
                sync_point();
                self.0.compare_exchange(current, new, success, failure)
            }
        }
    };
}

macro_rules! atomic_shim_arith {
    ($name:ident, $t:ty) => {
        impl $name {
            pub fn fetch_add(&self, v: $t, order: Ordering) -> $t {
                sync_point();
                self.0.fetch_add(v, order)
            }

            pub fn fetch_sub(&self, v: $t, order: Ordering) -> $t {
                sync_point();
                self.0.fetch_sub(v, order)
            }
        }
    };
}

atomic_shim!(AtomicBool, std::sync::atomic::AtomicBool, bool);
atomic_shim!(AtomicI64, std::sync::atomic::AtomicI64, i64);
atomic_shim!(AtomicU64, std::sync::atomic::AtomicU64, u64);
atomic_shim!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
atomic_shim_arith!(AtomicI64, i64);
atomic_shim_arith!(AtomicU64, u64);
atomic_shim_arith!(AtomicUsize, usize);

fn lock_raw<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // Poisoning under the model checker is always downstream of a failure
    // the scheduler has already recorded; never compound it here.
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Instrumented mutex. In scheduled mode, *contention* is resolved by the
/// scheduler (the OS lock is only ever taken uncontended, after the
/// scheduler grants ownership), so lock-acquisition order is a replayable
/// chooser decision.
#[derive(Debug)]
pub struct Mutex<T> {
    raw: std::sync::Mutex<T>,
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

impl<T> Mutex<T> {
    pub const fn new(value: T) -> Self {
        Self { raw: std::sync::Mutex::new(value) }
    }

    fn addr(&self) -> usize {
        self as *const Self as usize
    }

    pub fn into_inner(self) -> T {
        match self.raw.into_inner() {
            Ok(v) => v,
            Err(p) => p.into_inner(),
        }
    }

    pub fn lock(&self) -> MutexGuard<'_, T> {
        if let Some((s, me)) = op_sched() {
            s.maybe_yield(me);
            s.mutex_lock(me, self.addr());
            // The scheduler granted ownership: the OS lock is free (any
            // raw-mode holder is a mid-unwind bypass, which std resolves).
            let inner = lock_raw(&self.raw);
            return MutexGuard { lock: self, inner: Some(inner), sched: Some((s, me)) };
        }
        MutexGuard { lock: self, inner: Some(lock_raw(&self.raw)), sched: None }
    }
}

pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    /// `None` only transiently inside [`Condvar::wait`], which holds the
    /// guard by value.
    inner: Option<std::sync::MutexGuard<'a, T>>,
    /// Scheduler ownership to release on drop; `None` in passthrough or
    /// mid-unwind bypass acquisitions.
    sched: Option<(Arc<SchedInner>, usize)>,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        // invariant: `inner` is Some outside Condvar::wait, which owns
        // the guard by value while it is None.
        self.inner.as_deref().expect("treecv shim guard empty outside Condvar::wait")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // invariant: see Deref.
        self.inner.as_deref_mut().expect("treecv shim guard empty outside Condvar::wait")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Release the OS lock first, then the scheduler-level ownership,
        // so a scheduler-granted successor finds the OS lock free.
        self.inner.take();
        if let Some((s, me)) = self.sched.take() {
            s.mutex_unlock(me, self.lock.addr());
        }
    }
}

/// Instrumented condvar. In scheduled mode the wait set lives entirely in
/// the scheduler (the raw condvar is untouched), so which waiter a
/// `notify_one` wakes is a replayable chooser decision.
#[derive(Debug, Default)]
pub struct Condvar {
    raw: std::sync::Condvar,
}

impl Condvar {
    pub const fn new() -> Self {
        Self { raw: std::sync::Condvar::new() }
    }

    fn addr(&self) -> usize {
        self as *const Self as usize
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        match guard.sched.clone() {
            Some((s, me)) if !std::thread::panicking() => {
                let mutex_addr = guard.lock.addr();
                // Drop the OS lock; scheduler-level release + wait +
                // re-acquire happen atomically inside cond_wait.
                guard.inner.take();
                s.cond_wait(me, mutex_addr, self.addr());
                guard.inner = Some(lock_raw(&guard.lock.raw));
                guard
            }
            _ => {
                // invariant: `inner` is Some — this guard is held by
                // value and not inside another wait.
                let inner =
                    guard.inner.take().expect("treecv shim guard empty outside Condvar::wait");
                let inner = match self.raw.wait(inner) {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
                guard.inner = Some(inner);
                guard
            }
        }
    }

    pub fn notify_one(&self) {
        if let Some((s, me)) = op_sched() {
            s.maybe_yield(me);
            s.cond_notify(me, self.addr(), false);
            return;
        }
        self.raw.notify_one();
    }

    pub fn notify_all(&self) {
        if let Some((s, me)) = op_sched() {
            s.maybe_yield(me);
            s.cond_notify(me, self.addr(), true);
            return;
        }
        self.raw.notify_all();
    }
}

/// Scheduler-aware replacements for the `std::thread` services the
/// library uses (via `crate::sync::thread`).
pub mod thread {
    use super::*;

    pub use std::thread::{available_parallelism, panicking};

    #[derive(Clone)]
    enum Repr {
        Os(std::thread::Thread),
        V(Arc<SchedInner>, usize),
    }

    /// Handle to a thread for `unpark`, mirroring `std::thread::Thread`.
    #[derive(Clone)]
    pub struct Thread(Repr);

    impl std::fmt::Debug for Thread {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            match &self.0 {
                Repr::Os(t) => write!(f, "Thread::Os({:?})", t.id()),
                Repr::V(_, tid) => write!(f, "Thread::V({tid})"),
            }
        }
    }

    impl Thread {
        pub fn unpark(&self) {
            match &self.0 {
                Repr::Os(t) => t.unpark(),
                Repr::V(s, tid) => {
                    sync_point();
                    s.unpark(*tid);
                }
            }
        }
    }

    pub fn current() -> Thread {
        match current_sched() {
            Some((s, me)) => Thread(Repr::V(s, me)),
            None => Thread(Repr::Os(std::thread::current())),
        }
    }

    pub fn park() {
        match current_sched() {
            Some((s, me)) => {
                if std::thread::panicking() {
                    // Never real-park mid-unwind under a schedule: no
                    // vthread would deliver a raw unpark.
                    return;
                }
                s.park(me);
            }
            None => std::thread::park(),
        }
    }

    /// Scoped-thread shim. In scheduled mode each spawn registers a new
    /// vthread with the active scheduler, so executor worker threads
    /// become deterministic schedulable entities.
    pub struct Scope<'scope, 'env> {
        inner: &'scope std::thread::Scope<'scope, 'env>,
        sched: Option<Arc<SchedInner>>,
    }

    pub struct ScopedJoinHandle<'scope, T> {
        inner: std::thread::ScopedJoinHandle<'scope, T>,
        v: Option<(Arc<SchedInner>, usize)>,
    }

    pub fn scope<'env, F, T>(f: F) -> T
    where
        F: for<'scope> FnOnce(&Scope<'scope, 'env>) -> T,
    {
        let sched = current_sched().map(|(s, _)| s);
        std::thread::scope(move |s| f(&Scope { inner: s, sched }))
    }

    impl<'scope, 'env> Scope<'scope, 'env> {
        pub fn spawn<F, T>(&self, f: F) -> ScopedJoinHandle<'scope, T>
        where
            F: FnOnce() -> T + Send + 'scope,
            T: Send + 'scope,
        {
            match &self.sched {
                None => ScopedJoinHandle { inner: self.inner.spawn(f), v: None },
                Some(sched) => {
                    let tid = sched.register_vthread();
                    let sched2 = Arc::clone(sched);
                    let inner = self.inner.spawn(move || {
                        set_current(Arc::clone(&sched2), tid);
                        let r = catch_unwind(AssertUnwindSafe(|| {
                            sched2.wait_initial(tid);
                            f()
                        }));
                        clear_current();
                        sched2.finish_thread(tid, r.as_ref().err().map(|b| b.as_ref()));
                        match r {
                            Ok(v) => v,
                            // Keep std semantics: the join result carries
                            // the panic payload.
                            Err(p) => std::panic::resume_unwind(p),
                        }
                    });
                    ScopedJoinHandle { inner, v: Some((Arc::clone(sched), tid)) }
                }
            }
        }
    }

    impl<T> ScopedJoinHandle<'_, T> {
        pub fn join(self) -> std::thread::Result<T> {
            if let Some((sched, tid)) = &self.v {
                if !std::thread::panicking() {
                    if let Some((_, me)) = current_sched() {
                        // Block deterministically until the vthread
                        // finishes; the OS join below is then immediate.
                        sched.join(me, *tid);
                    }
                }
            }
            self.inner.join()
        }
    }
}
