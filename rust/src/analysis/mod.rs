//! Concurrency model-checking harness.
//!
//! Three pieces, composed by `tests/model_check.rs` and (under
//! `cfg(treecv_model_check)`) by the library's own [`crate::sync`]
//! gateway:
//!
//! - [`sched`] — a deterministic baton-passing scheduler over real OS
//!   threads, with a seeded pseudo-random chooser, a bounded-exhaustive
//!   stateless-DFS chooser, and exact trace/seed replay.
//! - [`shim`] — instrumented `Mutex`/`Condvar`/atomics/park primitives
//!   whose every operation is a scheduling decision point when a schedule
//!   is active, and raw `std` passthrough otherwise.
//! - [`protocols`] — miniature models of the executor's load-bearing
//!   protocols (park/unpark handshake, cancellation accounting, priority
//!   injector), each with seeded-bug mutations the checker must catch.
//!
//! This module is compiled unconditionally — no `cargo` flags needed to
//! run the checker — and is exempt from the `sync-gateway` repo lint
//! because it *implements* the layer beneath the gateway.

pub mod protocols;
pub mod sched;
pub mod shim;

#[cfg(test)]
mod tests {
    use super::protocols::*;
    use super::sched::*;

    fn cfg() -> ExploreCfg {
        ExploreCfg { preemption: Preemption::EveryOp, max_steps: 20_000 }
    }

    // Tiny smoke explorations — the heavy budgets live in
    // tests/model_check.rs; these keep the harness itself covered by the
    // plain unit suite (and by the targeted nightly Miri job).

    #[test]
    fn park_chain_correct_small_sweep() {
        let report =
            explore_random(|| park_chain(2, 2, ParkChainBug::Correct), 0..40, &cfg());
        assert!(report.all_ok(), "failures: {:?}", report.failures);
        assert_eq!(report.schedules, 40);
    }

    #[test]
    fn park_chain_seeded_bug_is_caught() {
        let report =
            explore_random(|| park_chain(2, 2, ParkChainBug::SkipDoneRecheck), 0..300, &cfg());
        assert!(!report.all_ok(), "seeded bug survived {} schedules", report.schedules);
    }

    #[test]
    fn dfs_exhausts_trivial_model() {
        let report = explore_dfs(|| handoff(1, 1, HandoffBug::Correct), 100_000, &cfg());
        assert!(report.exhausted, "space not exhausted in {} schedules", report.schedules);
        assert!(report.all_ok(), "failures: {:?}", report.failures);
    }

    #[test]
    fn failing_trace_replays_to_same_outcome() {
        let report =
            explore_random(|| handoff(1, 1, HandoffBug::SkipVerifySweep), 0..400, &cfg());
        assert!(!report.all_ok(), "seeded bug survived {} schedules", report.schedules);
        let fail = &report.failures[0];
        let replayed = replay(
            handoff(1, 1, HandoffBug::SkipVerifySweep),
            fail.trace.iter().map(|c| c.idx).collect(),
            &cfg(),
        );
        assert_eq!(replayed.outcome, fail.outcome);
        // And the seed alone reproduces it too.
        // invariant: random-exploration failures always carry their seed.
        let seed = fail.seed.expect("random failure has a seed");
        let reseeded = replay_seed(handoff(1, 1, HandoffBug::SkipVerifySweep), seed, &cfg());
        assert_eq!(reseeded.outcome, fail.outcome);
    }

    #[test]
    fn cancellation_accounting_small_sweep() {
        let report =
            explore_random(|| cancel_tree(4, 2, CancelBug::Correct), 0..40, &cfg());
        assert!(report.all_ok(), "failures: {:?}", report.failures);
    }

    #[test]
    fn priority_static_order_small_sweep() {
        let items = [(5, 500), (1, 100), (5, 501), (1, 101)];
        let report =
            explore_random(|| priority_static(&items, 2, PriorityBug::Correct), 0..40, &cfg());
        assert!(report.all_ok(), "failures: {:?}", report.failures);
    }

    #[test]
    fn priority_lifo_ties_is_caught() {
        let items = [(5, 500), (5, 501), (5, 502)];
        let report =
            explore_random(|| priority_static(&items, 2, PriorityBug::LifoTies), 0..10, &cfg());
        assert!(!report.all_ok(), "seeded bug survived {} schedules", report.schedules);
    }
}
