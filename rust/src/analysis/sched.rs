//! Deterministic interleaving scheduler for model checking.
//!
//! Real OS threads are serialized under a single "baton": exactly one
//! vthread runs at a time, and at every *decision point* (an instrumented
//! primitive op in [`Preemption::EveryOp`] mode, or an explicit
//! [`crate::analysis::shim::checkpoint`] / blocking op in
//! [`Preemption::ExplicitOnly`] mode) the scheduler consults a [`Chooser`]
//! to pick which runnable vthread goes next. Because every scheduler
//! interaction happens under one lock and the models themselves are
//! deterministic, the whole execution is a pure function of the chooser's
//! decisions — which is what makes seed replay and stateless DFS work.
//!
//! The design follows the shuttle/loom family of schedulers (PAPERS.md has
//! the background; this is the pragmatic randomized-plus-bounded-DFS end
//! of that spectrum, not a full partial-order-reduction checker).

use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, Once};

use crate::rng::Rng;

/// A concurrent protocol model: `n_threads` bodies sharing state through
/// the instrumented primitives in [`crate::analysis::shim`], plus a
/// final-state invariant checked after every thread has finished.
///
/// Bodies signal mid-run invariant violations via [`violate`]; liveness
/// failures surface as [`Outcome::Deadlock`] (no runnable thread) or
/// [`Outcome::TooLong`] (decision budget exhausted, i.e. livelock).
pub trait Model: Send + Sync {
    fn n_threads(&self) -> usize;
    fn thread(&self, tid: usize);
    fn check(&self) -> Result<(), String>;
}

/// Panic payload for a model-invariant violation (suppressed by the quiet
/// panic hook; converted to [`Outcome::Violation`] by the harness).
pub struct ModelViolation(pub String);

/// Panic payload used to unwind vthreads out of an aborted schedule
/// (deadlock detected, violation elsewhere, budget exhausted). Never
/// reported as a failure itself.
pub struct ScheduleAborted;

/// Abort the current schedule with a model-invariant violation.
pub fn violate(msg: impl Into<String>) -> ! {
    std::panic::panic_any(ModelViolation(msg.into()))
}

/// Fail the schedule unless `cond` holds.
pub fn model_assert(cond: bool, msg: &str) {
    if !cond {
        violate(msg);
    }
}

/// Where decision points occur.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preemption {
    /// Every instrumented primitive op yields — maximal interleaving,
    /// for randomized exploration.
    EveryOp,
    /// Only explicit `checkpoint()` calls and blocking ops yield —
    /// coarse action granularity that keeps DFS state spaces tractable.
    ExplicitOnly,
}

/// Picks the next runnable vthread (and the condvar waiter for
/// `notify_one`) at each decision point.
pub trait Chooser: Send {
    /// Return an index in `0..n`. `n >= 1`.
    fn choose(&mut self, n: usize) -> usize;
}

/// Seeded pseudo-random chooser; same seed → same schedule.
pub struct RandomChooser {
    rng: Rng,
}

impl RandomChooser {
    pub fn new(seed: u64) -> Self {
        // Tagged derivation keeps scheduler randomness independent of any
        // model-internal use of the same seed.
        Self { rng: Rng::derive(seed, 0x5eed_5c4e_d001) }
    }
}

impl Chooser for RandomChooser {
    fn choose(&mut self, n: usize) -> usize {
        if n <= 1 {
            0
        } else {
            self.rng.below(n as u64) as usize
        }
    }
}

/// Replays a recorded decision prefix, then always takes choice 0 —
/// the workhorse of stateless DFS and of exact trace replay.
pub struct PrefixChooser {
    prefix: Vec<usize>,
    pos: usize,
}

impl PrefixChooser {
    pub fn new(prefix: Vec<usize>) -> Self {
        Self { prefix, pos: 0 }
    }
}

impl Chooser for PrefixChooser {
    fn choose(&mut self, n: usize) -> usize {
        let i = if self.pos < self.prefix.len() {
            self.prefix[self.pos].min(n - 1)
        } else {
            0
        };
        self.pos += 1;
        i
    }
}

/// One recorded decision: `idx` of `n` candidates, resolving to vthread
/// (or condvar-waiter) `tid`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Choice {
    pub idx: usize,
    pub n: usize,
    pub tid: usize,
}

/// Verdict of one explored schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Outcome {
    Ok,
    /// No runnable vthread, but not all finished: lost wakeup or lock
    /// cycle. Carries a snapshot of who was blocked on what.
    Deadlock { blocked: Vec<(usize, String)> },
    /// Decision budget exhausted — livelock or runaway model.
    TooLong { steps: usize },
    /// A model invariant failed (mid-run `violate` or final `check`),
    /// or a vthread panicked outright.
    Violation { message: String },
}

impl Outcome {
    pub fn is_ok(&self) -> bool {
        matches!(self, Outcome::Ok)
    }
}

/// Result of running one schedule: the verdict plus the full decision
/// trace (replayable via [`PrefixChooser`]).
#[derive(Clone, Debug)]
pub struct ScheduleResult {
    pub outcome: Outcome,
    pub trace: Vec<Choice>,
}

/// Exploration knobs shared by the random and DFS drivers.
#[derive(Clone, Copy, Debug)]
pub struct ExploreCfg {
    pub preemption: Preemption,
    /// Max decisions per schedule before declaring [`Outcome::TooLong`].
    pub max_steps: usize,
}

impl Default for ExploreCfg {
    fn default() -> Self {
        Self { preemption: Preemption::EveryOp, max_steps: 20_000 }
    }
}

/// What a vthread is blocked on. Addresses identify shim primitives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Block {
    Park,
    Mutex(usize),
    Cond(usize),
    Join(usize),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum VState {
    Runnable,
    Blocked(Block),
    Finished,
}

struct SchedState {
    vstates: Vec<VState>,
    park_tokens: Vec<bool>,
    current: Option<usize>,
    /// Shim-mutex ownership, keyed by the primitive's address.
    mutex_owner: HashMap<usize, usize>,
    chooser: Box<dyn Chooser>,
    trace: Vec<Choice>,
    aborted: bool,
    outcome: Option<Outcome>,
    /// All vthreads finished (normally or via abort unwinding).
    done: bool,
    live: usize,
}

/// The shared scheduler core. Shim primitives reach it through the
/// thread-local installed around each vthread body.
pub struct SchedInner {
    st: Mutex<SchedState>,
    cv: Condvar,
    mode: Preemption,
    max_steps: usize,
}

thread_local! {
    static CURRENT: RefCell<Option<(Arc<SchedInner>, usize)>> = RefCell::new(None);
}

/// The scheduler driving this OS thread, if any. `None` means shim
/// primitives pass through to raw `std` behavior.
pub(crate) fn current_sched() -> Option<(Arc<SchedInner>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

pub(crate) fn set_current(sched: Arc<SchedInner>, tid: usize) {
    CURRENT.with(|c| *c.borrow_mut() = Some((sched, tid)));
}

pub(crate) fn clear_current() {
    CURRENT.with(|c| *c.borrow_mut() = None);
}

fn lock_poisonless<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // The scheduler lock is only ever held for short straight-line
    // bookkeeping; a poisoned guard still holds consistent state because
    // vthread panics unwind *outside* the critical sections below.
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

impl SchedInner {
    fn wait_cv<'a>(&self, st: MutexGuard<'a, SchedState>) -> MutexGuard<'a, SchedState> {
        match self.cv.wait(st) {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Abort the schedule: record `outcome` (first one wins), wake
    /// everyone so blocked vthreads can unwind via [`ScheduleAborted`].
    fn do_abort(&self, st: &mut SchedState, outcome: Outcome) {
        if st.outcome.is_none() {
            st.outcome = Some(outcome);
        }
        st.aborted = true;
        st.current = None;
        self.cv.notify_all();
    }

    /// Pick the next runnable vthread and hand it the baton. Called with
    /// the caller's claim on the baton already relinquished (blocked,
    /// finished, or about to re-contend).
    fn schedule_next(&self, st: &mut SchedState) {
        st.current = None;
        if st.aborted {
            return;
        }
        let runnable: Vec<usize> = (0..st.vstates.len())
            .filter(|&t| st.vstates[t] == VState::Runnable)
            .collect();
        if runnable.is_empty() {
            if st.live == 0 {
                st.done = true;
                self.cv.notify_all();
            } else {
                let blocked = st
                    .vstates
                    .iter()
                    .enumerate()
                    .filter_map(|(t, v)| match v {
                        VState::Blocked(b) => Some((t, format!("{b:?}"))),
                        _ => None,
                    })
                    .collect();
                self.do_abort(st, Outcome::Deadlock { blocked });
            }
            return;
        }
        let idx = st.chooser.choose(runnable.len());
        let tid = runnable[idx];
        st.trace.push(Choice { idx, n: runnable.len(), tid });
        if st.trace.len() > self.max_steps {
            self.do_abort(st, Outcome::TooLong { steps: st.trace.len() });
            return;
        }
        st.current = Some(tid);
        self.cv.notify_all();
    }

    /// Block until `me` holds the baton (runnable + chosen). Unwinds with
    /// [`ScheduleAborted`] if the schedule is aborted meanwhile.
    fn wait_turn<'a>(
        &self,
        me: usize,
        mut st: MutexGuard<'a, SchedState>,
    ) -> MutexGuard<'a, SchedState> {
        loop {
            if st.aborted {
                drop(st);
                std::panic::panic_any(ScheduleAborted);
            }
            if st.current == Some(me) && st.vstates[me] == VState::Runnable {
                return st;
            }
            st = self.wait_cv(st);
        }
    }

    /// Relinquish the baton, mark `me` blocked on `reason`, and return
    /// once woken *and* rescheduled.
    fn block_me<'a>(
        &self,
        me: usize,
        reason: Block,
        mut st: MutexGuard<'a, SchedState>,
    ) -> MutexGuard<'a, SchedState> {
        st.vstates[me] = VState::Blocked(reason);
        self.schedule_next(&mut st);
        self.wait_turn(me, st)
    }

    /// A decision point: offer the baton to any runnable vthread
    /// (possibly `me` again).
    pub(crate) fn yield_decision(&self, me: usize) {
        let mut st = lock_poisonless(&self.st);
        self.schedule_next(&mut st);
        let _st = self.wait_turn(me, st);
    }

    /// Decision point only in [`Preemption::EveryOp`] mode — the per-op
    /// hook used by the instrumented primitives.
    pub(crate) fn maybe_yield(&self, me: usize) {
        if self.mode == Preemption::EveryOp {
            self.yield_decision(me);
        }
    }

    pub(crate) fn park(&self, me: usize) {
        let mut st = lock_poisonless(&self.st);
        if st.park_tokens[me] {
            st.park_tokens[me] = false;
            // Consuming a banked token is still a decision point.
            self.schedule_next(&mut st);
            let _st = self.wait_turn(me, st);
        } else {
            let _st = self.block_me(me, Block::Park, st);
        }
    }

    pub(crate) fn unpark(&self, target: usize) {
        let mut st = lock_poisonless(&self.st);
        match st.vstates[target] {
            VState::Blocked(Block::Park) => st.vstates[target] = VState::Runnable,
            VState::Finished => {}
            // Matches std semantics: unpark of a non-parked thread banks
            // one token that the next park consumes.
            _ => st.park_tokens[target] = true,
        }
    }

    pub(crate) fn mutex_lock(&self, me: usize, addr: usize) {
        let mut st = lock_poisonless(&self.st);
        loop {
            if !st.mutex_owner.contains_key(&addr) {
                st.mutex_owner.insert(addr, me);
                return;
            }
            // Woken contenders re-check; the chooser decides who wins.
            st = self.block_me(me, Block::Mutex(addr), st);
        }
    }

    pub(crate) fn mutex_unlock(&self, _me: usize, addr: usize) {
        let mut st = lock_poisonless(&self.st);
        st.mutex_owner.remove(&addr);
        Self::wake_blocked_on(&mut st, Block::Mutex(addr));
    }

    fn wake_blocked_on(st: &mut SchedState, reason: Block) {
        for v in st.vstates.iter_mut() {
            if *v == VState::Blocked(reason) {
                *v = VState::Runnable;
            }
        }
    }

    pub(crate) fn cond_wait(&self, me: usize, mutex_addr: usize, cond_addr: usize) {
        let mut st = lock_poisonless(&self.st);
        // Atomically (under the scheduler lock) release the mutex and
        // join the condvar's wait set.
        st.mutex_owner.remove(&mutex_addr);
        Self::wake_blocked_on(&mut st, Block::Mutex(mutex_addr));
        st = self.block_me(me, Block::Cond(cond_addr), st);
        // Notified: re-acquire the mutex before returning, like std.
        loop {
            if !st.mutex_owner.contains_key(&mutex_addr) {
                st.mutex_owner.insert(mutex_addr, me);
                return;
            }
            st = self.block_me(me, Block::Mutex(mutex_addr), st);
        }
    }

    pub(crate) fn cond_notify(&self, _me: usize, cond_addr: usize, all: bool) {
        let mut st = lock_poisonless(&self.st);
        if all {
            Self::wake_blocked_on(&mut st, Block::Cond(cond_addr));
            return;
        }
        let waiters: Vec<usize> = (0..st.vstates.len())
            .filter(|&t| st.vstates[t] == VState::Blocked(Block::Cond(cond_addr)))
            .collect();
        if waiters.is_empty() {
            return;
        }
        // Which waiter `notify_one` wakes is itself nondeterministic —
        // a chooser decision like any other.
        let idx = st.chooser.choose(waiters.len());
        let tid = waiters[idx];
        st.trace.push(Choice { idx, n: waiters.len(), tid });
        st.vstates[tid] = VState::Runnable;
    }

    /// Register a dynamically spawned vthread (shim scoped spawn) and
    /// return its tid. The spawner keeps the baton; the new vthread waits
    /// its first turn like any other.
    pub(crate) fn register_vthread(&self) -> usize {
        let mut st = lock_poisonless(&self.st);
        let tid = st.vstates.len();
        st.vstates.push(VState::Runnable);
        st.park_tokens.push(false);
        st.live += 1;
        tid
    }

    pub(crate) fn join(&self, me: usize, target: usize) {
        let mut st = lock_poisonless(&self.st);
        if st.vstates[target] == VState::Finished {
            return;
        }
        let _st = self.block_me(me, Block::Join(target), st);
    }

    /// First baton wait of a freshly spawned vthread.
    pub(crate) fn wait_initial(&self, me: usize) {
        let st = lock_poisonless(&self.st);
        let _st = self.wait_turn(me, st);
    }

    pub(crate) fn finish_thread(
        &self,
        tid: usize,
        panic_payload: Option<&(dyn std::any::Any + Send)>,
    ) {
        let mut st = lock_poisonless(&self.st);
        st.vstates[tid] = VState::Finished;
        st.live -= 1;
        Self::wake_blocked_on(&mut st, Block::Join(tid));
        if let Some(p) = panic_payload {
            if p.is::<ScheduleAborted>() {
                // Cooperative unwind out of an already-aborted schedule.
            } else if let Some(v) = p.downcast_ref::<ModelViolation>() {
                self.do_abort(&mut st, Outcome::Violation { message: v.0.clone() });
            } else {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "vthread panicked (non-string payload)".into());
                self.do_abort(&mut st, Outcome::Violation { message: format!("panic: {msg}") });
            }
        }
        if st.aborted {
            if st.live == 0 {
                st.done = true;
            }
            self.cv.notify_all();
        } else {
            self.schedule_next(&mut st);
        }
    }
}

/// Suppress panic-hook noise for the two cooperative payloads; install
/// once, chain to the previous hook for everything else.
fn install_quiet_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let p = info.payload();
            if p.is::<ScheduleAborted>() || p.is::<ModelViolation>() {
                return;
            }
            prev(info);
        }));
    });
}

/// Run `model` once under `chooser`, returning the verdict and the
/// replayable decision trace.
pub fn run_schedule(
    model: Arc<dyn Model>,
    chooser: Box<dyn Chooser>,
    cfg: &ExploreCfg,
) -> ScheduleResult {
    install_quiet_hook();
    let n = model.n_threads();
    let inner = Arc::new(SchedInner {
        st: Mutex::new(SchedState {
            vstates: vec![VState::Runnable; n],
            park_tokens: vec![false; n],
            current: None,
            mutex_owner: HashMap::new(),
            chooser,
            trace: Vec::new(),
            aborted: false,
            outcome: None,
            done: false,
            live: n,
        }),
        cv: Condvar::new(),
        mode: cfg.preemption,
        max_steps: cfg.max_steps,
    });
    std::thread::scope(|s| {
        for tid in 0..n {
            let inner = Arc::clone(&inner);
            let model = Arc::clone(&model);
            s.spawn(move || {
                set_current(Arc::clone(&inner), tid);
                let r = catch_unwind(AssertUnwindSafe(|| {
                    inner.wait_initial(tid);
                    model.thread(tid);
                }));
                clear_current();
                inner.finish_thread(tid, r.as_ref().err().map(|b| b.as_ref()));
            });
        }
        let mut st = lock_poisonless(&inner.st);
        inner.schedule_next(&mut st);
        while !st.done {
            st = inner.wait_cv(st);
        }
    });
    let mut st = lock_poisonless(&inner.st);
    let trace = std::mem::take(&mut st.trace);
    let outcome = match st.outcome.take() {
        Some(o) => o,
        None => match model.check() {
            Ok(()) => Outcome::Ok,
            Err(message) => Outcome::Violation { message },
        },
    };
    ScheduleResult { outcome, trace }
}

/// A failed schedule with everything needed to reproduce it: the seed
/// (random exploration) and the exact decision trace (always).
#[derive(Clone, Debug)]
pub struct FailedSchedule {
    pub seed: Option<u64>,
    pub outcome: Outcome,
    pub trace: Vec<Choice>,
}

/// Aggregate result of an exploration sweep.
#[derive(Clone, Debug, Default)]
pub struct ExplorationReport {
    pub schedules: usize,
    pub failures: Vec<FailedSchedule>,
    /// DFS only: the whole bounded state space was enumerated.
    pub exhausted: bool,
}

impl ExplorationReport {
    pub fn all_ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Stop collecting after this many failing schedules — enough to
/// diagnose, without flooding the report on a badly broken model.
const MAX_FAILURES: usize = 3;

/// Randomized exploration: one schedule per seed in `seeds`.
pub fn explore_random<F>(
    factory: F,
    seeds: std::ops::Range<u64>,
    cfg: &ExploreCfg,
) -> ExplorationReport
where
    F: Fn() -> Arc<dyn Model>,
{
    let mut report = ExplorationReport::default();
    for seed in seeds {
        let r = run_schedule(factory(), Box::new(RandomChooser::new(seed)), cfg);
        report.schedules += 1;
        if !r.outcome.is_ok() {
            report.failures.push(FailedSchedule {
                seed: Some(seed),
                outcome: r.outcome,
                trace: r.trace,
            });
            if report.failures.len() >= MAX_FAILURES {
                break;
            }
        }
    }
    report
}

/// Bounded-exhaustive stateless DFS over decision prefixes: replay the
/// prefix, extend with first choices, then backtrack the deepest decision
/// with untried alternatives. Terminates with `exhausted = true` when the
/// space is fully enumerated within `max_schedules`.
pub fn explore_dfs<F>(factory: F, max_schedules: usize, cfg: &ExploreCfg) -> ExplorationReport
where
    F: Fn() -> Arc<dyn Model>,
{
    let mut report = ExplorationReport::default();
    let mut prefix: Vec<usize> = Vec::new();
    loop {
        if report.schedules >= max_schedules {
            return report;
        }
        let r = run_schedule(factory(), Box::new(PrefixChooser::new(prefix.clone())), cfg);
        report.schedules += 1;
        if !r.outcome.is_ok() {
            report.failures.push(FailedSchedule {
                seed: None,
                outcome: r.outcome.clone(),
                trace: r.trace.clone(),
            });
            if report.failures.len() >= MAX_FAILURES {
                return report;
            }
        }
        match next_prefix(&r.trace) {
            Some(p) => prefix = p,
            None => {
                report.exhausted = true;
                return report;
            }
        }
    }
}

/// Backtrack: deepest decision with an untried alternative, advanced by
/// one; `None` when the search tree is exhausted.
fn next_prefix(trace: &[Choice]) -> Option<Vec<usize>> {
    for i in (0..trace.len()).rev() {
        if trace[i].idx + 1 < trace[i].n {
            let mut p: Vec<usize> = trace[..i].iter().map(|c| c.idx).collect();
            p.push(trace[i].idx + 1);
            return Some(p);
        }
    }
    None
}

/// Replay one schedule from a recorded decision trace (`Choice::idx`
/// values) — exact reproduction of a failure found by either explorer.
pub fn replay(model: Arc<dyn Model>, prefix: Vec<usize>, cfg: &ExploreCfg) -> ScheduleResult {
    run_schedule(model, Box::new(PrefixChooser::new(prefix)), cfg)
}

/// Replay a random-exploration failure from its seed alone.
pub fn replay_seed(model: Arc<dyn Model>, seed: u64, cfg: &ExploreCfg) -> ScheduleResult {
    run_schedule(model, Box::new(RandomChooser::new(seed)), cfg)
}
