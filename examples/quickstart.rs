//! Quickstart: compute a 10-fold CV estimate for PEGASOS with TreeCV and
//! compare against the standard k-repetition method.
//!
//! Run: `cargo run --release --example quickstart`

use treecv::cv::folds::Folds;
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::CvEngine;
use treecv::data::synth::SyntheticCovertype;
use treecv::learner::pegasos::Pegasos;

fn main() {
    // 1. A dataset: 20k covertype-like points (54 features, ±1 labels).
    let n = 20_000;
    let data = SyntheticCovertype::new(n, 42).generate();

    // 2. An incremental learner: linear PEGASOS SVM.
    let learner = Pegasos::new(data.d, 1e-4);

    // 3. A fold assignment: k = 10 equal chunks.
    let folds = Folds::new(n, 10, 7);

    // 4. TreeCV (the paper's algorithm) vs the standard method.
    let tree = TreeCv::default().run(&learner, &data, &folds);
    let standard = StandardCv::default().run(&learner, &data, &folds);

    println!("10-fold CV misclassification estimates (n = {n}):");
    println!(
        "  treecv   : {:.4}  ({:>8} update-points, {:.3}s)",
        tree.estimate,
        tree.ops.points_updated,
        tree.wall.as_secs_f64()
    );
    println!(
        "  standard : {:.4}  ({:>8} update-points, {:.3}s)",
        standard.estimate,
        standard.ops.points_updated,
        standard.wall.as_secs_f64()
    );
    println!(
        "  work ratio standard/treecv = {:.2}x (theory: k/log2(2k) = {:.2}x)",
        standard.ops.points_updated as f64 / tree.ops.points_updated as f64,
        10.0 / (20f64).log2()
    );
    assert!((tree.estimate - standard.estimate).abs() < 0.05);
    println!("estimates agree — quickstart OK");
}
