//! Scratch perf driver #2 (§Perf pass): LSQSGD and k-means single-training
//! throughput, min-of-6. Not part of the documented examples.
use std::time::Instant;
use treecv::data::synth::{SyntheticBlobs, SyntheticYearMsd};
use treecv::learner::{kmeans::OnlineKMeans, lsqsgd::LsqSgd, IncrementalLearner};

fn main() {
    let n = 131_072;
    let data = SyntheticYearMsd::new(n, 42).generate();
    let l = LsqSgd::with_paper_step(90, n);
    let idx: Vec<u32> = (0..n as u32).collect();
    let mut best = f64::INFINITY;
    for _ in 0..6 {
        let t = Instant::now();
        let mut m = l.init();
        l.update(&mut m, &data, &idx);
        std::hint::black_box(&m);
        best = best.min(t.elapsed().as_secs_f64());
    }
    println!("lsqsgd single-training: {best:.5}s ({:.1} Mpts/s)", n as f64 / best / 1e6);
    let blobs = SyntheticBlobs::new(n, 16, 8, 42).generate();
    let k = OnlineKMeans::new(16, 8);
    let mut best = f64::INFINITY;
    for _ in 0..6 {
        let t = Instant::now();
        let mut m = k.init();
        k.update(&mut m, &blobs, &idx);
        std::hint::black_box(&m);
        best = best.min(t.elapsed().as_secs_f64());
    }
    println!("kmeans single-training: {best:.5}s ({:.1} Mpts/s)", n as f64 / best / 1e6);
}
