//! Scratch perf driver (used by the §Perf pass): single-training +
//! treecv-k64 timings on demand. Not part of the documented examples.
use treecv::cv::folds::Folds;
use treecv::cv::treecv::TreeCv;
use treecv::cv::CvEngine;
use treecv::data::synth::SyntheticCovertype;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::IncrementalLearner;
use std::time::Instant;
fn main() {
    let n = 131_072;
    let data = SyntheticCovertype::new(n, 42).generate();
    let l = Pegasos::new(data.d, 1e-5);
    let idx: Vec<u32> = (0..n as u32).collect();
    // warm
    let mut m = l.init();
    l.update(&mut m, &data, &idx);
    let mut best = f64::INFINITY;
    for _ in 0..7 {
        let t = Instant::now();
        let mut m = l.init();
        l.update(&mut m, &data, &idx);
        std::hint::black_box(&m);
        best = best.min(t.elapsed().as_secs_f64());
    }
    println!("single-training best: {best:.5}s ({:.1} Mpts/s)", n as f64/best/1e6);
    let folds = Folds::new(n, 64, 7);
    let mut bestt = f64::INFINITY;
    for _ in 0..5 {
        let t = Instant::now();
        std::hint::black_box(TreeCv::default().run(&l, &data, &folds));
        bestt = bestt.min(t.elapsed().as_secs_f64());
    }
    println!("treecv-k64 best: {bestt:.5}s");
    let folds = Folds::new_sorted(n, 64, 7);
    let mut bests = f64::INFINITY;
    for _ in 0..5 {
        let t = Instant::now();
        std::hint::black_box(TreeCv::default().run(&l, &data, &folds));
        bests = bests.min(t.elapsed().as_secs_f64());
    }
    println!("treecv-k64 sorted-chunks best: {bests:.5}s");
}
