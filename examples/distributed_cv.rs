//! Distributed TreeCV simulation (paper §4.1): chunks live on k storage
//! nodes; only the *model* moves over the (simulated) network, and the
//! total communication is O(k log k) model transfers — versus the naive
//! strategy that ships Θ(n·k) bytes of raw data to a compute node.
//!
//! Run: `cargo run --release --example distributed_cv`

use treecv::cv::folds::Folds;
use treecv::data::synth::SyntheticCovertype;
use treecv::distributed::{Cluster, NetworkModel};
use treecv::learner::pegasos::Pegasos;

fn main() {
    let n = 65_536;
    let data = SyntheticCovertype::new(n, 42).generate();
    let learner = Pegasos::new(data.d, 1e-5);
    let net = NetworkModel::default();

    println!("distributed CV on a simulated cluster (n = {n}, 100µs / 10Gb/s network)");
    println!(
        "{:>4} | {:>11} | {:>12} | {:>10} | {:>13} | {:>12} | {:>12}",
        "k", "model msgs", "2k·log2(2k)", "model MB", "naive data MB", "tree net(s)",
        "naive net(s)"
    );
    for k in [4usize, 8, 16, 32, 64, 128] {
        let folds = Folds::new(n, k, 13);
        let cluster = Cluster::new(&data, &folds, net.clone());
        let tree = cluster.treecv(&learner);
        let naive = cluster.standard_naive(&learner);
        let bound = 2.0 * k as f64 * ((2 * k) as f64).log2();
        println!(
            "{:>4} | {:>11} | {:>12.0} | {:>10.3} | {:>13.1} | {:>12.4} | {:>12.4}",
            k,
            tree.comm.model_messages,
            bound,
            tree.comm.model_bytes as f64 / 1e6,
            naive.comm.data_bytes as f64 / 1e6,
            tree.comm.sim_network_time_s,
            naive.comm.sim_network_time_s,
        );
        assert!((tree.estimate - naive.estimate).abs() < 0.05);
    }
    println!();
    println!(
        "model messages grow ~ k·log k; naive data movement grows ~ n·k — the paper's claim."
    );
}
