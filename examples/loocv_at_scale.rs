//! The paper's headline, end to end: leave-one-out cross-validation at
//! full Covertype scale (n = 581,012) with TreeCV, versus the standard
//! method which is only feasible at n ≈ 10,000 (the paper: "TreeCV makes
//! the calculation of LOOCV practical even for n = 581,012, in a fraction
//! of the time required by the standard method at n = 10,000").
//!
//! This is the end-to-end validation driver recorded in EXPERIMENTS.md:
//! it runs the full system on the paper-scale workload and reports the
//! paper's headline metric. Run:
//! `cargo run --release --example loocv_at_scale [n]`

use treecv::cv::folds::Folds;
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::CvEngine;
use treecv::data::synth::SyntheticCovertype;
use treecv::learner::pegasos::Pegasos;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(581_012);
    println!("generating covertype-like dataset, n = {n} ...");
    let data = SyntheticCovertype::new(n, 42).generate();
    let learner = Pegasos::new(data.d, 1e-6); // the paper's λ

    // --- TreeCV LOOCV at full scale -------------------------------------
    println!("TreeCV LOOCV (k = n = {n}) ...");
    let folds = Folds::loocv(n);
    let tree = TreeCv::default().run(&learner, &data, &folds);
    println!(
        "  estimate = {:.4} ({:.2}%)  wall = {:.2}s  update-points = {}",
        tree.estimate,
        100.0 * tree.estimate,
        tree.wall.as_secs_f64(),
        tree.ops.points_updated
    );

    // --- Standard LOOCV at the largest size the paper attempted ---------
    let n_std = 10_000.min(n);
    println!("Standard LOOCV at n = {n_std} (the paper's feasibility limit) ...");
    let small = data.take(n_std);
    let folds_std = Folds::loocv(n_std);
    let std_res = StandardCv::default().run(&learner, &small, &folds_std);
    println!(
        "  estimate = {:.4}  wall = {:.2}s  update-points = {}",
        std_res.estimate,
        std_res.wall.as_secs_f64(),
        std_res.ops.points_updated
    );

    // --- The headline ----------------------------------------------------
    let ratio = std_res.wall.as_secs_f64() / tree.wall.as_secs_f64().max(1e-9);
    println!();
    println!(
        "HEADLINE: TreeCV LOOCV at n={n} ran in {:.2}s — \
         {:.1}x {} than standard LOOCV at n={n_std} ({:.2}s)",
        tree.wall.as_secs_f64(),
        ratio.max(1.0 / ratio),
        if ratio >= 1.0 { "faster" } else { "slower" },
        std_res.wall.as_secs_f64()
    );
    let theoretical = ((2 * n) as f64).log2();
    println!(
        "  TreeCV did {:.1}x single-training work (theory bound: log2(2n) = {:.1}x)",
        tree.ops.points_updated as f64 / (n as f64 - 1.0),
        theoretical
    );
}
