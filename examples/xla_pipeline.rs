//! The full three-layer pipeline, end to end: the Rust TreeCV coordinator
//! (L3) drives a learner whose chunk update and evaluation execute the
//! AOT-compiled JAX + Pallas artifacts (L2/L1) through PJRT — Python never
//! runs. Cross-checks the XLA-backed estimate against the pure-Rust
//! learner.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example xla_pipeline`

use treecv::cv::folds::Folds;
use treecv::cv::treecv::TreeCv;
use treecv::cv::CvEngine;
use treecv::data::synth::{SyntheticCovertype, SyntheticYearMsd};
use treecv::learner::lsqsgd::LsqSgd;
use treecv::learner::pegasos::Pegasos;
use treecv::runtime::xla_learner::{XlaLsqSgd, XlaPegasos};
use treecv::runtime::{artifacts_available, Manifest, PjrtRuntime};

fn main() -> treecv::Result<()> {
    if !artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = PjrtRuntime::cpu()?;
    let manifest = Manifest::load_default()?;
    let n_programs = manifest.programs.len();
    println!("PJRT platform: {} — {n_programs} programs in manifest", rt.platform());

    // --- PEGASOS task (covertype-like, d=54) -----------------------------
    let n = 4_096;
    let k = 8;
    let data = SyntheticCovertype::new(n, 42).generate();
    let folds = Folds::new(n, k, 7);
    let lambda = 1e-3;

    let xla = XlaPegasos::from_manifest(&rt, &manifest, data.d, lambda)?;
    let t0 = std::time::Instant::now();
    let xla_res = TreeCv::default().run(&xla, &data, &folds);
    let xla_secs = t0.elapsed().as_secs_f64();

    let rust = Pegasos::new(data.d, lambda);
    let t0 = std::time::Instant::now();
    let rust_res = TreeCv::default().run(&rust, &data, &folds);
    let rust_secs = t0.elapsed().as_secs_f64();

    println!("PEGASOS {k}-fold CV, n = {n} (block size {}):", xla.block());
    println!("  xla/pallas learner : estimate {:.4}  ({:.3}s)", xla_res.estimate, xla_secs);
    println!("  pure-rust learner  : estimate {:.4}  ({:.3}s)", rust_res.estimate, rust_secs);
    assert!((xla_res.estimate - rust_res.estimate).abs() < 0.02);

    // --- LSQSGD task (yearmsd-like, d=90) --------------------------------
    let data = SyntheticYearMsd::new(n, 43).generate();
    let folds = Folds::new(n, k, 8);
    let alpha = 1.0 / (n as f64).sqrt();
    let xla = XlaLsqSgd::from_manifest(&rt, &manifest, data.d, alpha)?;
    let xla_res = TreeCv::default().run(&xla, &data, &folds);
    let rust = LsqSgd::new(data.d, alpha);
    let rust_res = TreeCv::default().run(&rust, &data, &folds);
    println!("LSQSGD {k}-fold CV, n = {n}:");
    println!("  xla/pallas learner : estimate {:.5}", xla_res.estimate);
    println!("  pure-rust learner  : estimate {:.5}", rust_res.estimate);
    assert!((xla_res.estimate - rust_res.estimate).abs() < 0.005);

    println!("three-layer pipeline OK — L3 rust coordinator → PJRT → L2 jax → L1 pallas");
    Ok(())
}
