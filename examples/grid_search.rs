//! Hyper-parameter tuning by fast CV — the paper's introductory motivation
//! ("one k-CV session needs to be run for every combination of
//! hyper-parameters ... dramatically increasing the computational cost").
//!
//! Tunes PEGASOS's λ over a log-grid with 10-fold CV computed by TreeCV,
//! and reports what the same grid would have cost with the standard
//! method. Run: `cargo run --release --example grid_search`

use treecv::cv::folds::Folds;
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::CvEngine;
use treecv::data::synth::SyntheticCovertype;
use treecv::learner::pegasos::Pegasos;

fn main() {
    let n = 30_000;
    let k = 10;
    let data = SyntheticCovertype::new(n, 42).generate();
    let folds = Folds::new(n, k, 11);
    let grid: Vec<f64> = (-6..=-1).map(|e| 10f64.powi(e)).collect();

    println!("tuning PEGASOS λ over {} grid points, {k}-fold CV, n = {n}", grid.len());
    println!("{:>10} | {:>12} | {:>12} | {:>10} | {:>10}",
             "lambda", "treecv est", "standard est", "tree(s)", "std(s)");

    let mut best = (f64::INFINITY, 0f64);
    let (mut tree_total, mut std_total) = (0f64, 0f64);
    for &lambda in &grid {
        let learner = Pegasos::new(data.d, lambda);
        let tree = TreeCv::default().run(&learner, &data, &folds);
        let standard = StandardCv::default().run(&learner, &data, &folds);
        tree_total += tree.wall.as_secs_f64();
        std_total += standard.wall.as_secs_f64();
        println!(
            "{:>10.0e} | {:>12.4} | {:>12.4} | {:>10.3} | {:>10.3}",
            lambda,
            tree.estimate,
            standard.estimate,
            tree.wall.as_secs_f64(),
            standard.wall.as_secs_f64()
        );
        if tree.estimate < best.0 {
            best = (tree.estimate, lambda);
        }
    }
    println!();
    println!("best λ = {:.0e} (CV misclassification {:.4})", best.1, best.0);
    println!(
        "grid total: treecv {:.2}s vs standard {:.2}s — {:.2}x saved on the whole search",
        tree_total,
        std_total,
        std_total / tree_total.max(1e-9)
    );
}
