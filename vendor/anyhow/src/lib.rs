//! Minimal, dependency-free subset of the `anyhow` error-handling API.
//!
//! The build image has no crates.io access, so the crate vendors exactly
//! the surface the repository uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the [`Context`] extension
//! trait. Semantics match upstream `anyhow` for these cases:
//!
//! * `Error` is a display-oriented error value that is NOT itself a
//!   `std::error::Error` (this is what lets the blanket `From<E>` impl
//!   coexist with the reflexive `From<Error>`), and it preserves the
//!   underlying error as a boxed `source`.
//! * `Context` prepends a message, like `anyhow`'s `"{context}: {cause}"`
//!   rendering of the chain head.

use std::error::Error as StdError;
use std::fmt;

/// A boxed, display-oriented error value.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `Result<T, anyhow::Error>` alias, with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an additional layer of context.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The chain of underlying causes, outermost first.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.source();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cause {
            write!(f, "\n    {e}")?;
            cause = e.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{context}: {e}"), source: Some(Box::new(e)) })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()), source: Some(Box::new(e)) })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!(
                concat!("condition failed: ", stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($tt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_preserves_message_and_source() {
        let e: Error = io_err().into();
        assert_eq!(format!("{e}"), "gone");
        assert!(e.source().is_some());
    }

    #[test]
    fn macros_build_errors() {
        let a = anyhow!("plain");
        assert_eq!(format!("{a}"), "plain");
        let x = 3;
        let b = anyhow!("x = {x}");
        assert_eq!(format!("{b}"), "x = 3");
        let c = anyhow!("{} and {}", 1, 2);
        assert_eq!(format!("{c}"), "1 and 2");
    }

    #[test]
    fn bail_and_ensure_return_early() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with flag {}", fail);
            if fail {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(format!("{}", f(true).unwrap_err()), "failed with flag true");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| "missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
