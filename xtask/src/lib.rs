//! Repo lint engine: the project-specific invariants that `rustc`,
//! `clippy`, and `rustfmt` cannot express. Run as `cargo run -p xtask --
//! lint` (blocking in CI; see `.github/workflows/ci.yml`).
//!
//! Rules (IDs are stable — fixture tests assert them):
//!
//! - `sync-gateway` — library code must reach concurrency primitives
//!   through `crate::sync`, never `std::sync` / `std::thread` directly,
//!   so the `cfg(treecv_model_check)` build can swap in the instrumented
//!   shim. Exempt: `rust/src/sync.rs` (the gateway) and
//!   `rust/src/analysis/` (the layer beneath it).
//! - `no-unwrap` — no `.unwrap()` / `.expect(` in library code unless a
//!   `// invariant:` comment within the preceding [`INVARIANT_WINDOW`]
//!   lines documents why the panic is unreachable. `#[cfg(test)]`
//!   regions are exempt (the repo convention keeps them at file tails).
//! - `line-width` — no source line over [`MAX_WIDTH`] characters.
//! - `opcounts-json` — every field of `metrics::OpCounts` must be
//!   serialized by the `ToJson` impl in `report.rs` (a silently dropped
//!   counter corrupts every downstream experiment report).
//! - `clone-from` — every learner/runtime model struct (`*Model` under
//!   `rust/src/learner/` or `rust/src/runtime/`) must have a hand-written
//!   `impl Clone` with a storage-reusing `clone_from` (the CV engines
//!   recycle snapshot buffers; a derived clone reallocates on every
//!   snapshot).
//! - `test-registration` — every `tests/*.rs` file must have a matching
//!   `[[test]]` entry in `Cargo.toml` (targets are not auto-discovered
//!   here; an unregistered suite silently never runs).
//! - `kernel-layer` — dense learner files must not contain inline
//!   dot/axpy-style per-feature loops; hot math routes through the
//!   SIMD-dispatched kernel layer in `rust/src/learner/linalg.rs` (which
//!   is itself exempt, as are `#[cfg(test)]` tails).

use std::collections::HashSet;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Maximum source-line width, in characters.
pub const MAX_WIDTH: usize = 100;

/// How many lines (including the flagged line) to scan backwards for a
/// `// invariant:` comment excusing an `.unwrap()` / `.expect(`.
pub const INVARIANT_WINDOW: usize = 12;

pub const SYNC_GATEWAY: &str = "sync-gateway";
pub const NO_UNWRAP: &str = "no-unwrap";
pub const LINE_WIDTH: &str = "line-width";
pub const OPCOUNTS_JSON: &str = "opcounts-json";
pub const CLONE_FROM: &str = "clone-from";
pub const TEST_REGISTRATION: &str = "test-registration";
pub const KERNEL_LAYER: &str = "kernel-layer";

/// One lint violation: stable rule ID, repo-relative path, 1-based line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}:{}: {}", self.rule, self.path, self.line, self.msg)
    }
}

fn finding(rule: &'static str, path: &str, line: usize, msg: String) -> Finding {
    Finding { rule, path: path.to_string(), line, msg }
}

fn is_comment(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// `sync-gateway`: flag lines referencing `std::sync` or `std::thread`
/// outside comments. The caller is responsible for exempting the gateway
/// itself and `rust/src/analysis/` (see [`lint_repo`]).
pub fn check_sync_gateway(path: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if is_comment(line) {
            continue;
        }
        for needle in ["std::sync", "std::thread"] {
            if line.contains(needle) {
                let msg =
                    format!("direct `{needle}` use; go through `crate::sync` (gateway lint)");
                out.push(finding(SYNC_GATEWAY, path, i + 1, msg));
                break;
            }
        }
    }
    out
}

/// `no-unwrap`: flag `.unwrap()` / `.expect(` outside `#[cfg(test)]`
/// regions unless a `// invariant:` comment appears within the preceding
/// [`INVARIANT_WINDOW`] lines.
pub fn check_no_unwrap(path: &str, text: &str) -> Vec<Finding> {
    let lines: Vec<&str> = text.lines().collect();
    let mut out = Vec::new();
    let mut in_tests = false;
    for (i, line) in lines.iter().enumerate() {
        if line.contains("#[cfg(test)]") {
            // Repo convention: unit tests live in a trailing module, so
            // everything from here down is test code.
            in_tests = true;
        }
        if in_tests || is_comment(line) {
            continue;
        }
        if !(line.contains(".unwrap()") || line.contains(".expect(")) {
            continue;
        }
        let lo = i.saturating_sub(INVARIANT_WINDOW - 1);
        let excused = lines[lo..=i].iter().any(|l| l.contains("invariant:"));
        if !excused {
            let msg = String::from(
                "`.unwrap()`/`.expect()` in library code without a nearby `// invariant:` \
                 comment — propagate a Result or document why the panic is unreachable",
            );
            out.push(finding(NO_UNWRAP, path, i + 1, msg));
        }
    }
    out
}

/// `line-width`: flag lines wider than [`MAX_WIDTH`] characters.
pub fn check_line_width(path: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let w = line.chars().count();
        if w > MAX_WIDTH {
            out.push(finding(LINE_WIDTH, path, i + 1, format!("{w} columns (max {MAX_WIDTH})")));
        }
    }
    out
}

/// `opcounts-json`: every `pub <field>:` of `pub struct OpCounts` in the
/// metrics source must appear as a `("<field>"` key in the report source.
pub fn check_opcounts_json(
    metrics_path: &str,
    metrics: &str,
    report_path: &str,
    report: &str,
) -> Vec<Finding> {
    let mut fields: Vec<(usize, String)> = Vec::new();
    let mut in_struct = false;
    for (i, line) in metrics.lines().enumerate() {
        if line.contains("pub struct OpCounts") {
            in_struct = true;
            continue;
        }
        if in_struct {
            let t = line.trim();
            if t.starts_with('}') {
                in_struct = false;
                break;
            }
            if let Some(rest) = t.strip_prefix("pub ") {
                if let Some((name, _)) = rest.split_once(':') {
                    fields.push((i + 1, name.trim().to_string()));
                }
            }
        }
    }
    let mut out = Vec::new();
    if fields.is_empty() {
        let msg = String::from("no `pub struct OpCounts` fields found — lint misconfigured?");
        out.push(finding(OPCOUNTS_JSON, metrics_path, 1, msg));
        return out;
    }
    for (line, name) in fields {
        if !report.contains(&format!("(\"{name}\"")) {
            let msg = format!(
                "OpCounts field `{name}` is not serialized by the ToJson impl in {report_path}"
            );
            out.push(finding(OPCOUNTS_JSON, metrics_path, line, msg));
        }
    }
    out
}

/// `clone-from`: every `pub struct <X>Model` declared in the file must
/// have a hand-written `impl Clone for <X>Model` containing `clone_from`.
pub fn check_clone_from(path: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let t = line.trim_start();
        let Some(rest) = t.strip_prefix("pub struct ") else {
            continue;
        };
        let name: String =
            rest.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
        if !name.ends_with("Model") {
            continue;
        }
        let imp = format!("impl Clone for {name}");
        let ok = match text.find(&imp) {
            Some(pos) => text[pos..].contains("fn clone_from"),
            None => false,
        };
        if !ok {
            let msg = format!(
                "model struct `{name}` needs a hand-written `impl Clone` with a \
                 storage-reusing `clone_from` (snapshot buffers are recycled)"
            );
            out.push(finding(CLONE_FROM, path, i + 1, msg));
        }
    }
    out
}

/// `kernel-layer`: dense learner files must not hand-roll dot/axpy-style
/// per-feature arithmetic — the hot math routes through the
/// SIMD-dispatched kernel layer (`rust/src/learner/linalg.rs`), and an
/// inline scalar loop silently bypasses both the dispatch and its
/// bit-identity test battery. Heuristic: a non-comment, non-test line that
/// compound-adds (`+=` / `-=`) a product into an indexed accumulator
/// (`y[i] += a * x[i]`) or accumulates an indexed product
/// (`s += x[i] * y[i]`). The caller exempts `linalg.rs` itself.
pub fn check_kernel_layer(path: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut in_tests = false;
    for (i, line) in text.lines().enumerate() {
        if line.contains("#[cfg(test)]") {
            // Same tail convention as `no-unwrap`: unit tests live in a
            // trailing module, exempt from the hot-path rule.
            in_tests = true;
        }
        if in_tests || is_comment(line) {
            continue;
        }
        let Some((lhs, rhs)) = line.split_once("+=").or_else(|| line.split_once("-=")) else {
            continue;
        };
        if !rhs.contains('*') {
            continue;
        }
        if lhs.contains('[') || rhs.matches('[').count() >= 2 {
            let msg = String::from(
                "inline dot/axpy-style arithmetic in a dense learner — route the hot \
                 math through the `linalg` kernel layer (SIMD dispatch + bit-identity \
                 battery)",
            );
            out.push(finding(KERNEL_LAYER, path, i + 1, msg));
        }
    }
    out
}

/// `test-registration`: every entry of `test_files` (repo-relative, e.g.
/// `tests/integration_cv.rs`) must appear as a `path = "..."` inside a
/// `[[test]]` section of the manifest.
pub fn check_test_registration(manifest: &str, test_files: &[String]) -> Vec<Finding> {
    let mut registered: HashSet<String> = HashSet::new();
    let mut in_test = false;
    for line in manifest.lines() {
        let t = line.trim();
        if t.starts_with('[') {
            in_test = t == "[[test]]";
            continue;
        }
        if in_test {
            if let Some(p) = t.strip_prefix("path = \"").and_then(|r| r.strip_suffix('"')) {
                registered.insert(p.to_string());
            }
        }
    }
    let mut out = Vec::new();
    for f in test_files {
        if !registered.contains(f) {
            let msg = format!(
                "{f} has no [[test]] entry in Cargo.toml — the suite silently never runs"
            );
            out.push(finding(TEST_REGISTRATION, f, 1, msg));
        }
    }
    out
}

/// Recursively collect `.rs` files, skipping `target/`, `vendor/`, and
/// fixture corpora. Missing directories are tolerated (empty result).
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in fs::read_dir(dir)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            let skip = matches!(
                p.file_name().and_then(|n| n.to_str()),
                Some("target") | Some("vendor") | Some("fixtures")
            );
            if !skip {
                walk(&p, out)?;
            }
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel(root: &Path, p: &Path) -> String {
    p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/")
}

/// Run every rule over the repository rooted at `root`; returns findings
/// sorted by (path, line, rule).
pub fn lint_repo(root: &Path) -> io::Result<Vec<Finding>> {
    let mut out = Vec::new();

    let mut src_files = Vec::new();
    walk(&root.join("rust/src"), &mut src_files)?;
    for p in &src_files {
        let path = rel(root, p);
        let text = fs::read_to_string(p)?;
        let gateway_exempt =
            path == "rust/src/sync.rs" || path.starts_with("rust/src/analysis/");
        if !gateway_exempt {
            out.extend(check_sync_gateway(&path, &text));
        }
        out.extend(check_no_unwrap(&path, &text));
        out.extend(check_line_width(&path, &text));
        if path.starts_with("rust/src/learner/") || path.starts_with("rust/src/runtime/") {
            out.extend(check_clone_from(&path, &text));
        }
        if path.starts_with("rust/src/learner/") && path != "rust/src/learner/linalg.rs" {
            out.extend(check_kernel_layer(&path, &text));
        }
    }

    for dir in ["tests", "benches", "examples", "xtask/src", "xtask/tests"] {
        let mut files = Vec::new();
        walk(&root.join(dir), &mut files)?;
        for p in files {
            let path = rel(root, &p);
            let text = fs::read_to_string(&p)?;
            out.extend(check_line_width(&path, &text));
        }
    }

    let metrics_path = "rust/src/metrics/mod.rs";
    let report_path = "rust/src/report.rs";
    let metrics = fs::read_to_string(root.join(metrics_path))?;
    let report = fs::read_to_string(root.join(report_path))?;
    out.extend(check_opcounts_json(metrics_path, &metrics, report_path, &report));

    let manifest = fs::read_to_string(root.join("Cargo.toml"))?;
    let mut tests: Vec<String> = Vec::new();
    for entry in fs::read_dir(root.join("tests"))? {
        let p = entry?.path();
        if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            if let Some(name) = p.file_name().and_then(|n| n.to_str()) {
                tests.push(format!("tests/{name}"));
            }
        }
    }
    tests.sort();
    out.extend(check_test_registration(&manifest, &tests));

    out.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(out)
}
