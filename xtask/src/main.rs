//! `cargo run -p xtask -- lint [--root <path>]`
//!
//! Exit status 0 when the tree is clean, 1 when any rule fires (findings
//! are printed one per line as `rule path:line: message`), 2 on usage or
//! I/O errors.

use std::path::PathBuf;
use std::process::ExitCode;

fn repo_root(args: &[String]) -> Option<PathBuf> {
    if let Some(i) = args.iter().position(|a| a == "--root") {
        return args.get(i + 1).map(PathBuf::from);
    }
    // xtask lives one level below the workspace root.
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().map(PathBuf::from)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            let Some(root) = repo_root(&args[1..]) else {
                eprintln!("xtask: could not determine repo root (pass --root <path>)");
                return ExitCode::from(2);
            };
            match xtask::lint_repo(&root) {
                Ok(findings) if findings.is_empty() => {
                    println!("xtask lint: clean ({})", root.display());
                    ExitCode::SUCCESS
                }
                Ok(findings) => {
                    for f in &findings {
                        println!("{f}");
                    }
                    eprintln!("xtask lint: {} finding(s)", findings.len());
                    ExitCode::FAILURE
                }
                Err(e) => {
                    eprintln!("xtask lint: I/O error: {e}");
                    ExitCode::from(2)
                }
            }
        }
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [--root <path>]");
            ExitCode::from(2)
        }
    }
}
