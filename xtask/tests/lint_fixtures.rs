//! Fixture battery for the repo lint: one known-violation file per rule
//! (asserting exact rule IDs and line numbers), one clean file that every
//! rule must pass, and a whole-repo run that doubles as the enforcement
//! test CI relies on.

use std::fs;
use std::path::Path;
use xtask::{
    check_clone_from, check_kernel_layer, check_line_width, check_no_unwrap,
    check_opcounts_json, check_sync_gateway, check_test_registration, lint_repo, CLONE_FROM,
    KERNEL_LAYER, LINE_WIDTH, NO_UNWRAP, OPCOUNTS_JSON, SYNC_GATEWAY, TEST_REGISTRATION,
};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

fn ids_and_lines(findings: &[xtask::Finding]) -> Vec<(&'static str, usize)> {
    findings.iter().map(|f| (f.rule, f.line)).collect()
}

#[test]
fn sync_gateway_flags_each_direct_use() {
    let f = check_sync_gateway("fx.rs", &fixture("sync_gateway.rs"));
    assert_eq!(
        ids_and_lines(&f),
        vec![(SYNC_GATEWAY, 3), (SYNC_GATEWAY, 6), (SYNC_GATEWAY, 7)]
    );
}

#[test]
fn no_unwrap_flags_undocumented_panics_only() {
    let f = check_no_unwrap("fx.rs", &fixture("no_unwrap.rs"));
    // Line 4: bare unwrap; line 8: expect without an invariant comment.
    // Line 13 (invariant-documented) and the cfg(test) unwrap are clean.
    assert_eq!(ids_and_lines(&f), vec![(NO_UNWRAP, 4), (NO_UNWRAP, 8)]);
}

#[test]
fn line_width_flags_the_wide_line() {
    let f = check_line_width("fx.rs", &fixture("line_width.rs"));
    assert_eq!(ids_and_lines(&f), vec![(LINE_WIDTH, 4)]);
    assert!(f[0].msg.contains("107"), "width missing from message: {}", f[0].msg);
}

#[test]
fn opcounts_json_flags_the_unserialized_field() {
    let f = check_opcounts_json(
        "fx_metrics.rs",
        &fixture("opcounts/metrics.rs"),
        "fx_report.rs",
        &fixture("opcounts/report.rs"),
    );
    assert_eq!(ids_and_lines(&f), vec![(OPCOUNTS_JSON, 5)]);
    assert!(f[0].msg.contains("missing_field"), "{}", f[0].msg);
}

#[test]
fn clone_from_flags_the_derived_model_only() {
    let f = check_clone_from("fx.rs", &fixture("clone_from.rs"));
    assert_eq!(ids_and_lines(&f), vec![(CLONE_FROM, 5)]);
    assert!(f[0].msg.contains("BadModel"), "{}", f[0].msg);
}

#[test]
fn test_registration_flags_the_unregistered_suite() {
    let manifest = fixture("test_reg/Cargo.toml");
    let files =
        vec!["tests/registered.rs".to_string(), "tests/unregistered.rs".to_string()];
    let f = check_test_registration(&manifest, &files);
    assert_eq!(ids_and_lines(&f), vec![(TEST_REGISTRATION, 1)]);
    assert_eq!(f[0].path, "tests/unregistered.rs");
}

#[test]
fn kernel_layer_flags_inline_hot_math_only() {
    let f = check_kernel_layer("fx.rs", &fixture("kernel_layer.rs"));
    // Lines 3/7/9: axpy-, dot- and negated-axpy-shaped inline loops;
    // line 14: an approx-path downdate sweep (mixed f64/f32). Line 20
    // (scalar `bias += eta * y`) and the cfg(test) tail are clean.
    assert_eq!(
        ids_and_lines(&f),
        vec![(KERNEL_LAYER, 3), (KERNEL_LAYER, 7), (KERNEL_LAYER, 9), (KERNEL_LAYER, 14)]
    );
}

#[test]
fn clean_file_passes_every_content_rule() {
    let text = fixture("clean.rs");
    assert!(check_sync_gateway("fx.rs", &text).is_empty());
    assert!(check_no_unwrap("fx.rs", &text).is_empty());
    assert!(check_line_width("fx.rs", &text).is_empty());
    assert!(check_clone_from("fx.rs", &text).is_empty());
    assert!(check_kernel_layer("fx.rs", &text).is_empty());
}

#[test]
fn whole_repo_is_clean() {
    // The enforcement test: the real tree must be lint-clean. CI also
    // runs `cargo run -p xtask -- lint` as a blocking step; this keeps
    // plain `cargo test -p xtask` equivalent.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("workspace root");
    let findings = lint_repo(root).expect("lint walk");
    assert!(
        findings.is_empty(),
        "repo lint findings:\n{}",
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}
