//! Fixture: model structs with and without a hand-written clone_from.

/// Derived Clone only — must be flagged (line 5).
#[derive(Clone)]
pub struct BadModel {
    pub w: Vec<f32>,
}

/// Hand-written Clone with storage reuse — clean.
pub struct GoodModel {
    pub w: Vec<f32>,
}

impl Clone for GoodModel {
    fn clone(&self) -> Self {
        Self { w: self.w.clone() }
    }

    fn clone_from(&mut self, src: &Self) {
        self.w.clone_from(&src.w);
    }
}

/// Not a model struct — never in scope for the rule.
#[derive(Clone)]
pub struct Config {
    pub k: usize,
}
