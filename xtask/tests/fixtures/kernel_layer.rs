pub fn bad(y: &mut [f32], x: &[f32], a: f32) {
    for i in 0..x.len() {
        y[i] += a * x[i];
    }
    let mut s = 0.0;
    for i in 0..x.len() {
        s += x[i] * x[i];
    }
    y[0] -= a * x[0];
}

pub fn bad_downdate(w: &mut [f64], g: f64, x: &[f32]) {
    for i in 0..x.len() {
        w[i] -= g * x[i] as f64;
    }
}

pub fn clean(t: &mut u64, bias: &mut f32, eta: f32, y: f32) {
    *t += 1;
    *bias += eta * y;
}

#[cfg(test)]
mod tests {
    #[test]
    fn exempt_inline_math() {
        let (mut y, x) = ([0f32; 4], [1f32; 4]);
        for i in 0..4 {
            y[i] += 2.0 * x[i];
        }
        assert_eq!(y[0], 2.0);
    }
}
