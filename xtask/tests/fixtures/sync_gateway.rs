//! Fixture: direct std::sync / std::thread uses (this line is prose).

use std::sync::Mutex;

pub fn fixture() -> Mutex<u32> {
    let m = std::sync::Mutex::new(1);
    let _ = std::thread::current();
    m
}
