//! Fixture: one over-wide line at line 4 (107 columns).

pub fn fits() {}
// aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa
pub fn also_fits() {}
