//! Fixture: OpCounts with a field the report serializer forgot.

pub struct OpCounts {
    pub update_calls: u64,
    pub missing_field: u64,
}
