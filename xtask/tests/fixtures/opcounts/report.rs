//! Fixture report: serializes update_calls but not missing_field.

pub fn to_json() -> String {
    let fields = [("update_calls", 1u64)];
    format!("{fields:?}")
}
