//! Fixture: a file that violates no rule. Comments may mention
//! std::sync or .unwrap() freely — prose is never flagged.

pub struct CleanModel {
    pub w: Vec<f32>,
}

impl Clone for CleanModel {
    fn clone(&self) -> Self {
        Self { w: self.w.clone() }
    }

    fn clone_from(&mut self, src: &Self) {
        self.w.clone_from(&src.w);
    }
}

pub fn total(model: &CleanModel) -> f32 {
    // invariant: documented panics are allowed when excused like this.
    let first = model.w.first().expect("caller guarantees non-empty");
    model.w.iter().sum::<f32>() + first - first
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(Some(3u32).unwrap(), 3);
    }
}
