//! Fixture: unwrap/expect in library positions (.unwrap() in prose ok).

pub fn bad_unwrap(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn bad_expect(v: Option<u32>) -> u32 {
    v.expect("present")
}

pub fn good_expect(v: Option<u32>) -> u32 {
    // invariant: callers always pass Some here.
    v.expect("always Some")
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(Some(1u32).unwrap(), 1);
    }
}
