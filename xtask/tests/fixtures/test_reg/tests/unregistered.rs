//! Fixture: this suite has NO [[test]] entry — must be flagged.

#[test]
fn absent_from_manifest() {}
