//! Fixture: this suite IS registered in the fixture Cargo.toml.

#[test]
fn present() {}
